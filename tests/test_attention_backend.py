"""SFC-native attention backend: differential + structural suite.

Differential: the band-scheduled flash forward against
`ref.flash_attention_ref`, its custom-VJP grads against XLA autodiff
(rtol 1e-4 at f32), the single-launch decode kernel against
`models.layers.decode_attention` — across causal/non-causal, GQA head
ratios, ragged/padded sequence lengths and bf16 inputs.

Structural: with ``attn_impl="sfc"`` and the sfc_pallas GEMM backend a
full train step's forward+backward jaxpr contains **zero** dot_general
(the attention extension of PR 3's projection gate); a decode step's
attention runs in exactly one Pallas launch; the kernels consult the
``attn_fwd``/``attn_bwd``/``attn_decode`` tune namespaces.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core import attention_backend as ab
from repro.kernels.ref import flash_attention_ref
from repro.kernels.sfc_attention import build_attention_task_table
from repro.models.layers import decode_attention as decode_ref


def _rand(*shape, dtype=jnp.float32, seed=0):
    rng = np.random.default_rng([seed, *[int(s) for s in shape]])
    return jnp.asarray(rng.normal(size=shape), dtype)


def _qkv(b, s, t, h, hkv, d, dtype=jnp.float32, seed=0):
    return (
        _rand(b, s, h, d, dtype=dtype, seed=seed),
        _rand(b, t, hkv, d, dtype=dtype, seed=seed + 1),
        _rand(b, t, hkv, d, dtype=dtype, seed=seed + 2),
    )


def _census(jaxpr, counts):
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            counts["pallas"] += 1
            continue
        if eqn.primitive.name == "dot_general":
            counts["dot"] += 1
            counts["dot_shapes"].append(
                tuple(tuple(v.aval.shape) for v in eqn.invars)
            )
        for val in eqn.params.values():
            _census_param(val, counts)
    return counts


def _census_param(val, counts):
    if isinstance(val, jax.core.ClosedJaxpr):
        _census(val.jaxpr, counts)
    elif isinstance(val, jax.core.Jaxpr):
        _census(val, counts)
    elif isinstance(val, (tuple, list)):
        for v in val:
            _census_param(v, counts)


def _count(fn, *args):
    jx = jax.make_jaxpr(fn)(*args)
    return _census(jx.jaxpr, {"dot": 0, "pallas": 0, "dot_shapes": []})


# ---------------------------------------------------------------------------
# task table
# ---------------------------------------------------------------------------


def test_band_table_drops_masked_tiles():
    """Causal tiles strictly above the diagonal are absent from the table —
    not pl.when-skipped — and each q row's tasks are contiguous with
    correct first/last flags."""
    tab = build_attention_task_table(
        4, 4, causal=True, q_chunk=16, k_chunk=16
    )
    # band row i has i+1 tiles -> 1+2+3+4 tasks, not 16
    assert tab.shape[1] == 10
    for t in range(tab.shape[1]):
        iq, ik = tab[0, t], tab[1, t]
        assert ik <= iq  # nothing above the diagonal
    # row-contiguity + flags
    rows = tab[0]
    changes = np.nonzero(np.diff(rows))[0]
    assert (np.sort(np.unique(rows)) == np.arange(4)).all()
    assert tab[2, 0] == 1 and tab[3, -1] == 1
    for c in changes:
        assert tab[3, c] == 1 and tab[2, c + 1] == 1


def test_band_table_serpentine_shares_boundary_panels():
    """Consecutive rows walk k in alternating directions, so at least one
    row boundary reuses the k panel of the previous task's neighbourhood
    (the boustrophedon quadrant-hop)."""
    tab = build_attention_task_table(
        4, 4, causal=False, q_chunk=16, k_chunk=16
    )
    assert tab.shape[1] == 16
    ks = tab[1].reshape(4, 4)
    assert (ks[0] == np.arange(4)).all()
    assert (ks[1] == np.arange(4)[::-1]).all()  # flipped row
    # boundary: last k of row 0 == first k of row 1
    assert ks[0, -1] == ks[1, 0]


def test_transpose_table_covers_causal_band():
    fwd = build_attention_task_table(3, 5, causal=True, q_chunk=32, k_chunk=16)
    bwd = build_attention_task_table(
        3, 5, causal=True, q_chunk=32, k_chunk=16, transpose=True
    )
    pairs_f = {(int(tab_q), int(tab_k)) for tab_q, tab_k in zip(fwd[0], fwd[1])}
    pairs_b = {(int(tab_q), int(tab_k)) for tab_k, tab_q in zip(bwd[0], bwd[1])}
    assert pairs_f == pairs_b


# ---------------------------------------------------------------------------
# forward differential
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize(
    "b,s,t,h,hkv,d",
    [
        (2, 32, 32, 4, 4, 16),  # MHA, chunk-aligned
        (2, 33, 33, 4, 2, 16),  # GQA 2:1, ragged seq
        (1, 16, 48, 8, 2, 8),   # GQA 4:1, cross-shaped (Sq != Sk)
        (1, 40, 24, 6, 6, 32),  # q longer than k, non-pow2 heads
    ],
)
def test_flash_fwd_matches_ref(causal, b, s, t, h, hkv, d):
    q, k, v = _qkv(b, s, t, h, hkv, d)
    got = ab.flash_attention(q, k, v, causal=causal, q_chunk=16, k_chunk=16)
    want = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5
    )


def test_flash_fwd_bf16():
    q, k, v = _qkv(2, 33, 33, 4, 2, 16, dtype=jnp.bfloat16)
    got = ab.flash_attention(q, k, v, causal=True, q_chunk=16, k_chunk=16)
    want = flash_attention_ref(q, k, v, causal=True)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=5e-2, atol=5e-2,
    )


# ---------------------------------------------------------------------------
# backward differential
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize(
    "b,s,t,h,hkv,d",
    [
        (2, 32, 32, 4, 4, 16),
        (2, 33, 33, 4, 2, 16),
        (1, 16, 48, 8, 2, 8),
    ],
)
def test_flash_grads_match_xla(causal, b, s, t, h, hkv, d):
    """custom-VJP dQ/dK/dV kernels vs XLA autodiff of the dense reference
    at f32 rtol 1e-4 — GQA included (dK/dV sum over the head group)."""
    q, k, v = _qkv(b, s, t, h, hkv, d)
    w = _rand(b, s, h, d, seed=9)

    def f_sfc(q, k, v):
        o = ab.flash_attention(q, k, v, causal=causal, q_chunk=16, k_chunk=16)
        return jnp.sum(o.astype(jnp.float32) * w)

    def f_ref(q, k, v):
        o = flash_attention_ref(q, k, v, causal=causal)
        return jnp.sum(o.astype(jnp.float32) * w)

    gs = jax.grad(f_sfc, argnums=(0, 1, 2))(q, k, v)
    gx = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for got, want, name in zip(gs, gx, "qkv"):
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5,
            err_msg=f"d{name} causal={causal}",
        )


def test_flash_grad_is_three_pallas_launches_no_dots():
    """grad(flash) = fwd + dQ + dK/dV launches, zero dot_general — the
    attention analogue of the NT/TN structural gate."""
    q, k, v = _qkv(1, 32, 32, 4, 2, 16)
    c = _count(
        lambda q, k, v: ab.flash_attention(
            q, k, v, causal=True, q_chunk=16, k_chunk=16
        ).sum(),
        q, k, v,
    )
    assert c["pallas"] == 1 and c["dot"] == 0
    c = _count(
        jax.grad(
            lambda q, k, v: ab.flash_attention(
                q, k, v, causal=True, q_chunk=16, k_chunk=16
            ).astype(jnp.float32).sum(),
            argnums=(0, 1, 2),
        ),
        q, k, v,
    )
    assert c["dot"] == 0, f"attention backward fell back: {c['dot_shapes']}"
    assert c["pallas"] == 3, f"expected fwd+dQ+dKV launches, saw {c['pallas']}"


# ---------------------------------------------------------------------------
# chunked prefill (q_offset)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("off", [16, 40])
def test_flash_fwd_q_offset_matches_full_causal(off):
    """A q block at global rows [off, off+s) with q_offset=off reproduces
    the matching slice of full-sequence causal attention — the chunked
    prefill identity."""
    b, t, h, hkv, d, s = 2, 96, 4, 2, 32, 32
    q_full, k, v = _qkv(b, t, t, h, hkv, d)
    want = flash_attention_ref(q_full, k, v, causal=True)
    got = ab.flash_attention(
        q_full[:, off : off + s], k, v, causal=True,
        q_chunk=16, k_chunk=16, q_offset=off,
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want[:, off : off + s]),
        rtol=1e-5, atol=1e-5,
    )


def test_flash_fwd_q_offset_zero_is_identity():
    q, k, v = _qkv(1, 33, 33, 4, 2, 16)
    base = ab.flash_attention(q, k, v, causal=True, q_chunk=16, k_chunk=16)
    off0 = ab.flash_attention(
        q, k, v, causal=True, q_chunk=16, k_chunk=16, q_offset=0
    )
    np.testing.assert_array_equal(np.asarray(base), np.asarray(off0))


def test_flash_q_offset_grads_match_xla():
    """custom-VJP grads at a nonzero KV-cache offset vs XLA autodiff of an
    offset-masked dense reference, f32 rtol 1e-4."""
    b, s, t, h, hkv, d, off = 1, 32, 80, 4, 2, 16, 40
    q, k, v = _qkv(b, s, t, h, hkv, d)
    w = _rand(b, s, h, d, seed=9)

    def f_sfc(q, k, v):
        o = ab.flash_attention(
            q, k, v, causal=True, q_chunk=16, k_chunk=16, q_offset=off
        )
        return jnp.sum(o.astype(jnp.float32) * w)

    def f_ref(q, k, v):
        kr = jnp.repeat(k, h // hkv, axis=2)
        vr = jnp.repeat(v, h // hkv, axis=2)
        sc = jnp.einsum(
            "bqhd,bkhd->bhqk", q, kr, preferred_element_type=jnp.float32
        ) / np.sqrt(d)
        mask = (
            jnp.arange(t)[None, :] <= jnp.arange(s)[:, None] + off
        )
        sc = jnp.where(mask[None, None], sc, -1e30)
        o = jnp.einsum(
            "bhqk,bkhd->bqhd",
            jax.nn.softmax(sc, axis=-1),
            vr.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        return jnp.sum(o * w)

    gs = jax.grad(f_sfc, argnums=(0, 1, 2))(q, k, v)
    gx = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for got, want, name in zip(gs, gx, "qkv"):
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5,
            err_msg=f"d{name} q_offset={off}",
        )


def test_flash_q_offset_negative_rejected():
    q, k, v = _qkv(1, 16, 16, 2, 2, 8)
    with pytest.raises(ValueError, match="q_offset"):
        ab.flash_attention(q, k, v, causal=True, q_offset=-1)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "b,t,h,hkv,d,valids",
    [
        (3, 40, 8, 2, 16, (1, 17, 40)),   # ragged live lengths
        (2, 32, 4, 4, 8, (32, 5)),        # MHA
        (1, 64, 16, 2, 32, (33,)),        # deep GQA 8:1
    ],
)
def test_decode_matches_ref(b, t, h, hkv, d, valids):
    q = _rand(b, 1, h, d)
    k = _rand(b, t, hkv, d, seed=1)
    v = _rand(b, t, hkv, d, seed=2)
    valid = jnp.asarray(valids, jnp.int32)
    got = ab.decode_attention(q, k, v, valid)
    want = decode_ref(q, k, v, valid)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5
    )


def test_decode_is_single_pallas_launch():
    """The whole (batch, head) decode fan-out is ONE pallas_call — no
    per-head einsum fan-out, no dot_general."""
    q = _rand(2, 1, 8, 16)
    k = _rand(2, 32, 2, 16, seed=1)
    v = _rand(2, 32, 2, 16, seed=2)
    valid = jnp.asarray([5, 32], jnp.int32)
    c = _count(lambda q, k, v: ab.decode_attention(q, k, v, valid), q, k, v)
    assert c["pallas"] == 1, f"decode used {c['pallas']} launches"
    assert c["dot"] == 0, f"decode fell back to dot_general: {c['dot_shapes']}"


def test_model_decode_step_single_attention_launch_per_layer():
    """`attention_decode` under attn_impl='sfc' launches exactly one Pallas
    kernel for the attention math (projections pinned to xla here so the
    count isolates attention)."""
    from repro.models import attention as attn

    cfg = _tiny_cfg()
    p = attn.attention_init(
        jax.random.PRNGKey(0), d_model=cfg.d_model, n_heads=cfg.n_heads,
        kv_heads=cfg.kv_heads, head_dim=cfg.head_dim_,
    )
    x = _rand(2, 1, cfg.d_model)
    cache = {
        "k": jnp.zeros((2, 32, cfg.kv_heads, cfg.head_dim_)),
        "v": jnp.zeros((2, 32, cfg.kv_heads, cfg.head_dim_)),
    }
    idx = jnp.asarray(3, jnp.int32)

    def step(x, cache):
        o, _ = attn.attention_decode(
            p, x, cache, idx,
            n_heads=cfg.n_heads, kv_heads=cfg.kv_heads, attn_impl="sfc",
        )
        return o

    c = _count(step, x, cache)
    assert c["pallas"] == 1
    # remaining dots are the xla projections (rank-2 weights) only
    for shp in c["dot_shapes"]:
        assert any(len(op) == 2 for op in shp), shp


# ---------------------------------------------------------------------------
# full-model structural gates
# ---------------------------------------------------------------------------


def _tiny_cfg(**kw):
    return ArchConfig(
        name="tiny_sfc_attn", family="dense", n_layers=2, d_model=32,
        n_heads=4, kv_heads=2, d_ff=48, vocab=64, head_dim=8,
        param_dtype="float32", q_chunk=16, k_chunk=16, attn_impl="sfc",
        **kw,
    )


def test_train_step_jaxpr_is_dot_general_free():
    """Acceptance: with attn_impl='sfc' + the sfc_pallas GEMM backend, the
    FULL forward+backward train-step jaxpr contains zero dot_general —
    attention scores included (PR 3 only gated rank-2 projections)."""
    from repro.models.registry import build_model
    from repro.optim.adamw import AdamWConfig, adamw_init
    from repro.train.step import BackendConfig, make_train_step

    cfg = _tiny_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)), jnp.int32),
    }
    step = make_train_step(
        model, AdamWConfig(lr=1e-3), remat="none", backend=BackendConfig(gemm_backend="sfc_pallas"))
    jx = jax.make_jaxpr(step)(params, adamw_init(params), batch)
    c = _census(jx.jaxpr, {"dot": 0, "pallas": 0, "dot_shapes": []})
    assert c["pallas"] > 0
    assert c["dot"] == 0, (
        f"dot_general survived the SFC train step: {c['dot_shapes']}"
    )


def test_train_step_grads_match_xla_with_sfc_attention():
    """Numerics: the dot_general-free step advances params identically to
    the XLA/blockwise step at f32."""
    from repro.models.registry import build_model
    from repro.optim.adamw import AdamWConfig, adamw_init
    from repro.train.step import BackendConfig, make_train_step

    cfg = _tiny_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)), jnp.int32),
    }
    opt = AdamWConfig(lr=1e-3)
    step_s = make_train_step(
        model, opt, remat="none", backend=BackendConfig(gemm_backend="sfc_pallas"))
    step_x = make_train_step(
        model, opt, remat="none", backend=BackendConfig(gemm_backend="xla", attn_impl="blockwise"))
    p_s, _, m_s = step_s(params, adamw_init(params), batch)
    p_x, _, m_x = step_x(params, adamw_init(params), batch)
    np.testing.assert_allclose(
        float(m_s["loss"]), float(m_x["loss"]), rtol=1e-4
    )
    for ls, lx in zip(jax.tree.leaves(p_s), jax.tree.leaves(p_x)):
        np.testing.assert_allclose(
            np.asarray(ls), np.asarray(lx), rtol=1e-4, atol=1e-5
        )


def test_attention_backend_context_overrides_config():
    """The contextvar pin (make_train_step's attn_impl=...) wins over the
    per-call config value at trace time."""
    q, k, v = _qkv(1, 16, 16, 2, 2, 8)
    from repro.models.attention import _attend

    with ab.attention_backend("sfc"):
        c = _count(
            lambda q, k, v: _attend(
                q, k, v, causal=True, q_chunk=16, k_chunk=16,
                attn_impl="blockwise",
            ).sum(),
            q, k, v,
        )
    assert c["pallas"] == 1 and c["dot"] == 0
    with pytest.raises(ValueError):
        ab.attention_backend("nope").__enter__()


def test_prefill_respects_attn_impl():
    """Regression (bugfix): attention_prefill previously hardwired
    blockwise_attention regardless of attn_impl."""
    from repro.models import attention as attn

    cfg = _tiny_cfg()
    p = attn.attention_init(
        jax.random.PRNGKey(0), d_model=cfg.d_model, n_heads=cfg.n_heads,
        kv_heads=cfg.kv_heads, head_dim=cfg.head_dim_,
    )
    x = _rand(2, 16, cfg.d_model)

    def prefill(x):
        o, _ = attn.attention_prefill(
            p, x, n_heads=cfg.n_heads, kv_heads=cfg.kv_heads, cache_len=32,
            q_chunk=16, k_chunk=16, attn_impl="sfc",
        )
        return o

    c = _count(prefill, x)
    assert c["pallas"] == 1, "prefill ignored attn_impl='sfc'"
    # and the two impls agree numerically
    o_sfc, cache_sfc = attn.attention_prefill(
        p, x, n_heads=cfg.n_heads, kv_heads=cfg.kv_heads, cache_len=32,
        q_chunk=16, k_chunk=16, attn_impl="sfc",
    )
    o_blk, cache_blk = attn.attention_prefill(
        p, x, n_heads=cfg.n_heads, kv_heads=cfg.kv_heads, cache_len=32,
        q_chunk=16, k_chunk=16, attn_impl="blockwise",
    )
    np.testing.assert_allclose(
        np.asarray(o_sfc), np.asarray(o_blk), rtol=1e-5, atol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(cache_sfc["k"]), np.asarray(cache_blk["k"]), rtol=1e-6
    )


def test_serving_prefill_decode_agree_across_impls():
    """End-to-end model prefill+decode under attn_impl='sfc' matches the
    blockwise implementation (greedy tokens identical)."""
    from repro.models.registry import build_model

    outs = {}
    for impl in ("blockwise", "sfc"):
        cfg = dataclasses.replace(_tiny_cfg(), attn_impl=impl)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        tokens = jnp.asarray(rng.integers(0, cfg.vocab, (2, 12)), jnp.int32)
        logits, cache = model.prefill(params, tokens, cache_len=24, remat="none")
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        seq = [np.asarray(tok)]
        for _ in range(3):
            logits, cache = model.decode_step(params, tok, cache)
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            seq.append(np.asarray(tok))
        outs[impl] = np.concatenate(seq, axis=1)
    np.testing.assert_array_equal(outs["sfc"], outs["blockwise"])


# ---------------------------------------------------------------------------
# tune-namespace integration
# ---------------------------------------------------------------------------


def test_attn_tune_namespaces_consulted(tmp_path, monkeypatch):
    """flash_attention resolves op='attn_fwd' (and the backward
    op='attn_bwd') from the tune cache; a cached winner steers the chunk
    knobs without changing the numbers."""
    import repro.tune
    import repro.tune.tuner as tuner
    from repro.tune import Knobs

    monkeypatch.setenv("REPRO_SFC_TUNE_CACHE", str(tmp_path / "knobs.json"))
    tuner._DEFAULT_CACHE = None
    try:
        cache = tuner.default_cache()
        cache.put(
            64, 64, 16, np.float32, "cpu",
            Knobs(bm=32, bn=16, k_layers=1, k_block_factor=1), op="attn_fwd",
        )
        seen = []
        real = repro.tune.lookup_knobs

        def spy(m_, n_, k_, dtype, **kw):
            hit = real(m_, n_, k_, dtype, **kw)
            seen.append(((m_, n_, k_), kw.get("op"), hit))
            return hit

        monkeypatch.setattr(repro.tune, "lookup_knobs", spy)
        q, k, v = _qkv(1, 64, 64, 2, 2, 16)
        want = flash_attention_ref(q, k, v, causal=True)
        got = ab.flash_attention(q, k, v, causal=True)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5
        )
        fwd_hits = [hit for (_, op, hit) in seen if op == "attn_fwd"]
        assert fwd_hits and fwd_hits[0] is not None
        assert fwd_hits[0].bm == 32 and fwd_hits[0].bn == 16

        jax.grad(
            lambda q: ab.flash_attention(q, k, v, causal=True).sum()
        )(q)
        assert any(op == "attn_bwd" for (_, op, _) in seen)
    finally:
        tuner._DEFAULT_CACHE = None


def test_attn_cached_winner_overrides_config_hint(tmp_path, monkeypatch):
    """Model configs always pass q_chunk/k_chunk, so the measured winner
    must take precedence over the hint — a hint-wins rule would leave the
    whole attn tuning pipeline inert for every model path (regression)."""
    import repro.tune.tuner as tuner
    from repro.tune import Knobs

    monkeypatch.setenv("REPRO_SFC_TUNE_CACHE", str(tmp_path / "knobs.json"))
    tuner._DEFAULT_CACHE = None
    try:
        tuner.default_cache().put(
            64, 64, 16, np.float32, "cpu",
            Knobs(bm=32, bn=16, k_layers=1, k_block_factor=1), op="attn_fwd",
        )
        qc, kc = ab.resolve_attn_knobs(
            64, 64, 16, jnp.float32, op="attn_fwd", q_chunk=64, k_chunk=64
        )
        assert (qc, kc) == (32, 16), "cached winner lost to the config hint"
        # no winner -> the hint stands
        qc, kc = ab.resolve_attn_knobs(
            64, 64, 16, jnp.float32, op="attn_bwd", q_chunk=64, k_chunk=64
        )
        assert (qc, kc) == (64, 64)
        # and the full model path picks the winner up (flash_attention
        # receives the config chunks yet launches with the tuned ones)
        q, k, v = _qkv(1, 64, 64, 2, 2, 16)
        want = flash_attention_ref(q, k, v, causal=True)
        got = ab.flash_attention(q, k, v, causal=True, q_chunk=64, k_chunk=64)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5
        )
    finally:
        tuner._DEFAULT_CACHE = None


def test_tune_gemm_measures_attn_namespaces(tmp_path, monkeypatch):
    """tune_gemm accepts the attn namespaces end-to-end (simulator-scored
    on CPU) and persists winners the resolver can read back."""
    from repro.tune import KnobCache, lookup_knobs, tune_gemm

    monkeypatch.setenv("REPRO_SFC_TUNE_CACHE", str(tmp_path / "k.json"))
    import repro.tune.tuner as tuner

    tuner._DEFAULT_CACHE = None
    try:
        cache = KnobCache(str(tmp_path / "k.json"))

        def fake_measure(m, n, k, dtype, knobs, *, op="gemm"):
            return float(knobs.bm + knobs.bn)  # deterministic argmin

        for op in ("attn_fwd", "attn_bwd", "attn_decode"):
            got = tune_gemm(
                64, 64, 16, np.float32, cache=cache,
                measure_fn=fake_measure, op=op,
            )
            assert got.source == "measured"
            hit = lookup_knobs(64, 64, 16, np.float32, cache=cache, op=op)
            assert hit is not None and hit.bm == got.bm
    finally:
        tuner._DEFAULT_CACHE = None


def test_serving_tune_table_includes_attn_rows():
    from repro.models.registry import build_model  # noqa: F401
    from repro.serving.engine import ServingEngine

    cfg = _tiny_cfg()
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    eng = ServingEngine(
        cfg, params, max_batch=2, max_seq=32, gemm_backend="sfc_pallas"
    )
    table = eng.tune_table(16, backward=True)
    ops = [op for (op, *_ ) in table]
    assert "attn_fwd" in ops and "attn_bwd" in ops and "attn_decode" in ops
    decode_row = [r for r in table if r[0] == "attn_decode"][0]
    assert decode_row[1:] == (cfg.n_heads, 32, cfg.head_dim_)
    # the blockwise config emits no attention namespaces
    cfg_blk = dataclasses.replace(cfg, attn_impl="blockwise")
    eng2 = ServingEngine(cfg_blk, params, max_batch=2, max_seq=32)
    assert not any(op.startswith("attn") for (op, *_ ) in eng2.tune_table(16))


# ---------------------------------------------------------------------------
# perf-model attention terms
# ---------------------------------------------------------------------------


def test_flash_simulation_band_census():
    from repro.core.perf_model import (
        simulate_flash_attention,
        unfused_attention_bytes,
    )

    r = simulate_flash_attention(
        1, 8, 1024, 1024, 64, q_chunk=128, k_chunk=128, causal=True,
        phase="fwd", hkv=2,
    )
    # causal band: nq(nq+1)/2 tiles of an 8x8 grid
    assert r["n_tiles"] == 36
    assert r["bytes"] > 0 and r["time_s"] > 0
    full = simulate_flash_attention(
        1, 8, 1024, 1024, 64, q_chunk=128, k_chunk=128, causal=False,
        phase="fwd", hkv=2,
    )
    assert full["n_tiles"] == 64 and full["bytes"] > r["bytes"]
    # the flash schedule moves far fewer bytes than materialized scores
    assert unfused_attention_bytes(1, 8, 1024, 1024, 64) > 3 * r["bytes"]
    bwd = simulate_flash_attention(
        1, 8, 1024, 1024, 64, q_chunk=128, k_chunk=128, causal=True,
        phase="bwd", hkv=2,
    )
    assert bwd["flops"] > r["flops"]


def test_decode_simulation_valid_bound():
    from repro.core.perf_model import (
        simulate_decode_attention,
        unfused_decode_attention_bytes,
    )

    half = simulate_decode_attention(8, 32, 4, 8192, 128, valid_frac=0.5)
    full = simulate_decode_attention(8, 32, 4, 8192, 128, valid_frac=1.0)
    assert half["bytes"] < full["bytes"]
    # head expansion + dead-chunk reads make the unfused path strictly worse
    assert unfused_decode_attention_bytes(8, 32, 4, 8192, 128) > full["bytes"]
