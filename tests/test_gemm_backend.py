"""gemm_backend routing: n-D matmul reaches the batched SFC kernel, the
grouped hook serves MoE expert GEMMs, and every backend agrees numerically."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.gemm_backend as gb
from repro.core.gemm_backend import gemm_backend, grouped_matmul, matmul


def _rand(*shape, dtype=jnp.float32, seed=0):
    rng = np.random.default_rng([seed, *shape])
    return jnp.asarray(rng.normal(size=shape), dtype)


def test_matmul_2d_all_backends_agree():
    x, w = _rand(24, 40), _rand(40, 16, seed=1)
    want = x @ w
    for backend in ("xla", "sfc_pallas", "sfc_reference"):
        with gemm_backend(backend):
            got = matmul(x, w)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5,
            err_msg=backend,
        )


@pytest.mark.parametrize("lead", [(3,), (2, 5)])
def test_matmul_nd_routes_to_batched_kernel(lead, monkeypatch):
    """3-D/4-D activations must launch the batched SFC grid, not a reshape."""
    import repro.kernels.ops as ops

    calls = []
    real = ops.sfc_gemm_batched_fused

    def spy(a, b, *args, **kw):
        calls.append(a.shape)
        return real(a, b, *args, **kw)

    monkeypatch.setattr(ops, "sfc_gemm_batched_fused", spy)
    x, w = _rand(*lead, 12, 32), _rand(32, 20, seed=2)
    with gemm_backend("sfc_pallas"):
        got = matmul(x, w)
    assert calls, "n-D matmul must go through sfc_gemm_batched_fused"
    assert calls[0] == (int(np.prod(lead)), 12, 32)  # leading dims folded
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(x @ w), rtol=3e-5, atol=3e-5
    )


def test_grouped_matmul_all_backends_agree():
    x = _rand(2, 4, 6, 16)  # (G, E, C, d)
    w = _rand(4, 16, 12, seed=3)  # (E, d, f)
    want = jnp.einsum("gecd,edf->gecf", x, w)
    for backend in ("xla", "sfc_pallas", "sfc_reference"):
        with gemm_backend(backend):
            got = grouped_matmul(x, w)
        assert got.shape == (2, 4, 6, 12)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5,
            err_msg=backend,
        )


def test_grouped_matmul_no_lead_dims():
    x = _rand(3, 5, 8)  # (E, C, d) — the shard_map body shape
    w = _rand(3, 8, 6, seed=4)
    want = jnp.einsum("ecd,edf->ecf", x, w)
    with gemm_backend("sfc_pallas"):
        got = grouped_matmul(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)


def test_moe_forward_sfc_backend_matches_xla():
    """The whole MoE layer (routing + dispatch + expert GEMMs + combine)
    agrees between the einsum path and the grouped SFC kernel path."""
    from repro.models.moe import moe_forward, moe_init

    p = moe_init(jax.random.PRNGKey(5), d_model=16, d_ff=32, n_experts=4,
                 dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 8, 16)) * 0.5
    out_xla, aux_xla = moe_forward(p, x, top_k=2, capacity_factor=2.0)
    with gemm_backend("sfc_pallas"):
        out_sfc, aux_sfc = moe_forward(p, x, top_k=2, capacity_factor=2.0)
    np.testing.assert_allclose(
        np.asarray(out_xla), np.asarray(out_sfc), rtol=3e-5, atol=3e-5
    )
    np.testing.assert_allclose(
        float(aux_xla["moe_aux_loss"]), float(aux_sfc["moe_aux_loss"]), rtol=1e-5
    )


def test_backend_contextvar_restores():
    assert gb.current_backend() == "xla"
    with gemm_backend("sfc_pallas"):
        assert gb.current_backend() == "sfc_pallas"
        with gemm_backend("sfc_reference"):
            assert gb.current_backend() == "sfc_reference"
        assert gb.current_backend() == "sfc_pallas"
    assert gb.current_backend() == "xla"
    with pytest.raises(ValueError):
        with gemm_backend("nope"):
            pass
