"""HLO static-cost parser: validated against analytically-known programs
(this is the cost source behind EXPERIMENTS.md SSRoofline)."""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Runs in a subprocess with 8 devices so the SPMD/collective paths are real.
_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.roofline.hlo_cost import module_cost, parse_module

mesh = jax.make_mesh((2, 4), ("data", "model"))
L, B, S, D = 5, 4, 32, 64

def f(x, w):
    def body(x, wi):
        return jnp.tanh(x @ wi), None
    x, _ = lax.scan(body, x, w)
    return (x * x).sum()

x = jax.ShapeDtypeStruct((B, S, D), jnp.float32)
w = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
with mesh:
    comp = jax.jit(jax.grad(f, argnums=(0, 1)), in_shardings=(
        NamedSharding(mesh, P("data", None, "model")),
        NamedSharding(mesh, P(None, None, "model")))).lower(x, w).compile()
c = module_cost(comp.as_text())
# per device: fwd + dgrad + wgrad dots per layer, x L layers (loop-aware!)
expect = L * 3 * 2 * (2 * 32) * 64 * 16
ratio = c.flops / expect
assert 0.95 < ratio < 1.1, f"flops ratio {ratio}"
assert c.total_coll_bytes > 0, "collectives must be visible"
assert c.bytes > 0

# nested scans multiply
def g(x, w):
    def outer(x, wi):
        def inner(x, _):
            return jnp.tanh(x @ wi), None
        x, _ = lax.scan(inner, x, None, length=3)
        return x, None
    x, _ = lax.scan(outer, x, w)
    return x.sum()

with mesh:
    comp2 = jax.jit(g, in_shardings=(
        NamedSharding(mesh, P("data", None, "model")),
        NamedSharding(mesh, P(None, None, "model")))).lower(x, w).compile()
c2 = module_cost(comp2.as_text())
expect2 = L * 3 * 2 * (2 * 32) * 64 * 16
ratio2 = c2.flops / expect2
assert 0.9 < ratio2 < 1.2, f"nested ratio {ratio2}"
print("HLO_COST_OK")
"""


def test_parser_exact_on_known_programs():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True, timeout=420, env=env
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "HLO_COST_OK" in proc.stdout


def test_parser_handles_metadata_parens():
    """Regression: metadata strings contain parens; attrs must survive."""
    from repro.roofline.hlo_cost import _parse_op_line

    line = (
        '  %w = f32[2]{0} fusion(%a, %b), kind=kLoop, calls=%comp, '
        'metadata={op_name="jit(f)/jvp()/while/body/add" stack_frame_id=3}'
    )
    name, shape, opcode, args, attrs = _parse_op_line(line)
    assert opcode == "fusion"
    assert "calls=%comp" in attrs
    assert args == "%a, %b"


def test_trip_count_from_backend_config():
    from repro.roofline.hlo_cost import Op, _trip_count

    op = Op(
        "w", "(s32[])", "while", ["%t"],
        'condition=%c, body=%b, backend_config={"known_trip_count":{"n":"80"}}',
    )
    assert _trip_count({}, op, "c") == 80
