"""Pallas SFC-CA GEMM kernel: shape/dtype sweeps vs the pure-jnp oracle
(interpret mode on CPU), plus the Listing-1 reference algorithm."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hypothesis optional: property test skips, rest run
    given = settings = st = None

from repro.core.sfc_gemm import sfc_ca_gemm_reference
from repro.kernels.ops import pick_blocks, sfc_matmul
from repro.kernels.ref import add_reduce_ref, matmul_ref, partial_k_matmul_ref
from repro.kernels.sfc_gemm import add_reduce_pallas, build_task_table, sfc_gemm_pallas

def _mats(m, n, k, dtype):
    rng = np.random.default_rng([m, n, k, np.dtype(dtype).itemsize])
    a = jnp.asarray(rng.normal(size=(m, k)), dtype)
    b = jnp.asarray(rng.normal(size=(k, n)), dtype)
    return a, b


SHAPES = [
    # (m, n, k, bm, bn, k_layers, kbf, dtypes) — every shape in f32, the
    # knob-extreme ones also in bf16 (dtype casework is shape-insensitive)
    (32, 32, 32, 16, 16, 1, 1, (jnp.float32, jnp.bfloat16)),
    (64, 32, 64, 16, 16, 2, 1, (jnp.float32,)),
    (32, 64, 128, 16, 16, 1, 4, (jnp.float32,)),
    (64, 64, 64, 32, 32, 2, 2, (jnp.float32,)),
    (128, 32, 64, 16, 16, 4, 1, (jnp.float32, jnp.bfloat16)),
    (48, 80, 96, 16, 16, 2, 3, (jnp.float32, jnp.bfloat16)),  # non-pow2 grid
]


@pytest.mark.parametrize(
    "m,n,k,bm,bn,kl,kbf,dtype",
    [s[:7] + (dt,) for s in SHAPES for dt in s[7]],
)
def test_sfc_gemm_pallas_sweep(m, n, k, bm, bn, kl, kbf, dtype):
    a, b = _mats(m, n, k, dtype)
    got = sfc_matmul(a, b, bm=bm, bn=bn, k_layers=kl, k_block_factor=kbf, interpret=True)
    want = matmul_ref(a, b)
    tol = 2e-5 if dtype == jnp.float32 else 6e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


def test_partial_copies_match_k_slabs():
    """The (K_layers, M, N) replicated-C stage equals per-slab products."""
    a, b = _mats(32, 32, 64, jnp.float32)
    copies = sfc_gemm_pallas(a, b, bm=16, bn=16, k_layers=2, interpret=True)
    want = partial_k_matmul_ref(a, b, 2)
    np.testing.assert_allclose(np.asarray(copies), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_add_reduce_kernel():
    rng = np.random.default_rng(7)
    c = jnp.asarray(rng.normal(size=(4, 32, 48)), jnp.float32)
    got = add_reduce_pallas(c, bm=16, bn=16, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(add_reduce_ref(c)), rtol=1e-6)


def test_task_table_is_listing1_order():
    """Task t = layer-major, gilbert order within layer (Listing 1 12-14)."""
    tab = build_task_table(4, 4, 2)
    assert tab.shape == (3, 32)
    assert (tab[2, :16] == 0).all() and (tab[2, 16:] == 1).all()
    assert (tab[:2, :16] == tab[:2, 16:]).all()  # same SFC order per layer
    steps = np.abs(np.diff(tab[0, :16])) + np.abs(np.diff(tab[1, :16]))
    assert (steps == 1).all()  # gilbert adjacency


def _check_padding_case(m, n, k):
    a, b = _mats(m, n, k, jnp.float32)
    got = sfc_matmul(a, b, bm=16, bn=16, k_layers=1, k_block_factor=1, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(a) @ np.asarray(b), rtol=3e-5, atol=3e-5
    )


@pytest.mark.parametrize("m,n,k", [(4, 8, 8), (34, 21, 95), (64, 9, 33)])
def test_sfc_matmul_padding_smoke(m, n, k):
    """Non-divisible shapes via zero padding — hypothesis-free sample."""
    _check_padding_case(m, n, k)


if st is None:

    def test_padding_property_needs_hypothesis():
        pytest.importorskip("hypothesis")  # visible skip, not silent drop

else:

    @given(
        m=st.integers(2, 9).map(lambda e: 2**e // 2 * 2),
        n=st.integers(8, 96),
        k=st.integers(8, 96),
    )
    @settings(max_examples=12, deadline=None)
    def test_sfc_matmul_arbitrary_shapes_padding(m, n, k):
        """Arbitrary (non-divisible) shapes via zero padding."""
        _check_padding_case(m, n, k)


BATCHED_SHAPES = [
    # (lead, m, n, k, kwargs, dtype)
    ((3,), 32, 32, 32, dict(bm=16, bn=16, k_layers=1, k_block_factor=1), jnp.float32),
    ((3,), 32, 32, 32, dict(bm=16, bn=16, k_layers=1, k_block_factor=1), jnp.bfloat16),
    ((2,), 48, 80, 96, dict(bm=16, bn=16, k_layers=2, k_block_factor=3), jnp.float32),
    # padding path, 4-D lead, 2.5D layers
    ((2, 2), 37, 21, 53, dict(bm=16, bn=16, k_layers=2, k_block_factor=2), jnp.float32),
    ((2, 2), 37, 21, 53, dict(bm=16, bn=16, k_layers=2, k_block_factor=2), jnp.bfloat16),
    ((4,), 19, 45, 30, dict(), jnp.float32),  # knobs from model/cache
]


@pytest.mark.parametrize("lead,m,n,k,kw,dtype", BATCHED_SHAPES)
def test_sfc_matmul_batched_shared_weights(lead, m, n, k, kw, dtype):
    """(..., M, K) @ (K, N): batched grid, one task table, shared B."""
    rng = np.random.default_rng([m, n, k, len(lead)])
    a = jnp.asarray(rng.normal(size=(*lead, m, k)), dtype)
    b = jnp.asarray(rng.normal(size=(k, n)), dtype)
    got = sfc_matmul(a, b, interpret=True, **kw)
    want = jnp.matmul(a, b)
    assert got.shape == (*lead, m, n)
    tol = 3e-5 if dtype == jnp.float32 else 6e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


def test_sfc_matmul_batched_per_batch_weights():
    """(B, M, K) @ (B, K, N): per-batch B panels."""
    rng = np.random.default_rng(11)
    a = jnp.asarray(rng.normal(size=(3, 24, 40)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(3, 40, 28)), jnp.float32)
    got = sfc_matmul(a, b, bm=16, bn=16, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(jnp.matmul(a, b)), rtol=3e-5, atol=3e-5
    )


def test_sfc_matmul_batched_matches_unbatched():
    """Each batch element equals the 2-D kernel on that element."""
    rng = np.random.default_rng(12)
    a = jnp.asarray(rng.normal(size=(2, 32, 32)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(32, 32)), jnp.float32)
    got = sfc_matmul(a, b, bm=16, bn=16, k_layers=2, interpret=True)
    for i in range(2):
        one = sfc_matmul(a[i], b, bm=16, bn=16, k_layers=2, interpret=True)
        np.testing.assert_allclose(np.asarray(got[i]), np.asarray(one), rtol=1e-6)


GROUPED_CASES = [
    # (group_sizes, k, n, dtype)
    ((5, 0, 19, 32), 24, 18, jnp.float32),  # ragged incl. empty expert
    ((5, 0, 19, 32), 24, 18, jnp.bfloat16),
    ((16, 16), 32, 32, jnp.float32),  # uniform, divisible
    ((1, 2, 3), 7, 9, jnp.float32),  # tiny odd dims
]


@pytest.mark.parametrize("group_sizes,k,n,dtype", GROUPED_CASES)
def test_sfc_grouped_matmul_ragged(group_sizes, k, n, dtype):
    from repro.kernels.ops import sfc_grouped_matmul

    rng = np.random.default_rng([sum(group_sizes), k, n])
    a = jnp.asarray(rng.normal(size=(sum(group_sizes), k)), dtype)
    w = jnp.asarray(rng.normal(size=(len(group_sizes), k, n)), dtype)
    got = sfc_grouped_matmul(a, w, group_sizes, bm=16, bn=16, interpret=True)
    off, parts = 0, []
    for e, g in enumerate(group_sizes):
        parts.append(jnp.matmul(a[off : off + g], w[e]))
        off += g
    want = jnp.concatenate(parts)
    assert got.shape == (sum(group_sizes), n)
    tol = 3e-5 if dtype == jnp.float32 else 6e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


def test_grouped_task_table_layout():
    """Per-expert gilbert maps, concatenated with padded row offsets."""
    from repro.kernels.sfc_gemm import build_grouped_task_table

    tab = build_grouped_task_table((2, 0, 3), 4)
    assert tab.shape == (3, (2 + 3) * 4)
    # expert 0 tasks first, rows 0-1; expert 2 next, rows 2-4
    assert (tab[2, : 2 * 4] == 0).all() and (tab[2, 2 * 4 :] == 2).all()
    assert tab[0, : 2 * 4].min() == 0 and tab[0, : 2 * 4].max() == 1
    assert tab[0, 2 * 4 :].min() == 2 and tab[0, 2 * 4 :].max() == 4
    # gilbert adjacency within each expert's walk
    for sl in (slice(0, 8), slice(8, 20)):
        steps = np.abs(np.diff(tab[0, sl])) + np.abs(np.diff(tab[1, sl]))
        assert (steps >= 1).all() and (steps <= 2).all()


def test_reference_matches_oracle_knob_grid():
    """Listing-1 reference across the paper's (K_layers, kbf) knob grid."""
    a, b = _mats(64, 64, 128, jnp.float32)
    want = np.asarray(a) @ np.asarray(b)
    for kl in (1, 2, 4):
        for kbf in (1, 2):
            got = sfc_ca_gemm_reference(
                a, b, bm=16, bn=16, bk=16, k_layers=kl, k_block_factor=kbf
            )
            np.testing.assert_allclose(np.asarray(got), want, rtol=2e-5, atol=2e-5)


def test_pick_blocks_mxu_alignment():
    assert pick_blocks(1024, 2048, 512) == (256, 256, 256)
    assert pick_blocks(48, 80, 96)[0] in (16, 48)


@pytest.mark.parametrize(
    "b,s,t,h,hkv,d,causal,dtype",
    [
        # f32 across the shape sweep, bf16 on two representatives — each
        # (shape, dtype) pair compiles its own interpret kernel, and the
        # bf16 casework is dtype-, not shape-, sensitive
        (2, 64, 64, 4, 2, 16, True, jnp.float32),
        (1, 96, 96, 2, 2, 32, True, jnp.float32),
        (2, 48, 48, 4, 1, 16, False, jnp.float32),
        (1, 40, 72, 2, 2, 16, True, jnp.float32),
        (2, 33, 50, 2, 1, 16, True, jnp.float32),  # non-divisible: padding
        (2, 64, 64, 4, 2, 16, True, jnp.bfloat16),
        (2, 33, 50, 2, 1, 16, True, jnp.bfloat16),
    ],
)
def test_flash_attention_sweep(b, s, t, h, hkv, d, causal, dtype):
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.ref import flash_attention_ref

    rng = np.random.default_rng(s + t + h)
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, t, hkv, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, t, hkv, d)), dtype)
    got = flash_attention(q, k, v, causal=causal, q_chunk=16, k_chunk=16, interpret=True)
    want = flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 6e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


def test_flash_causal_band_guard_skips_masked_chunks():
    """Regression for the dead causal-band guard: k chunks fully above the
    diagonal must be *skipped*, not computed-and-masked.  NaNs are planted
    in the k/v rows of the last k chunk; any q chunk below the band would
    only stay NaN-free if the guard actually predicates the MXU work off
    (0 * NaN inside a computed dot would be NaN)."""
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.ref import flash_attention_ref

    b, s, h, d, chunk = 1, 64, 2, 16, 16
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = np.asarray(rng.normal(size=(b, s, h, d)), np.float32)
    v = np.asarray(rng.normal(size=(b, s, h, d)), np.float32)
    # poison the last k chunk: fully masked for every q chunk except the last
    k[:, -chunk:] = np.nan
    v[:, -chunk:] = np.nan
    got = flash_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
        causal=True, q_chunk=chunk, k_chunk=chunk, interpret=True,
    )
    clean = np.asarray(got)[:, : s - chunk]
    assert np.isfinite(clean).all(), (
        "fully-masked k chunks contributed MXU work (NaN leaked through "
        "the causal-band guard)"
    )
    want = flash_attention_ref(
        jnp.asarray(q[:, : s - chunk]), jnp.asarray(k[:, : s - chunk]),
        jnp.asarray(v[:, : s - chunk]), causal=True,
    )
    np.testing.assert_allclose(
        clean, np.asarray(want), rtol=2e-5, atol=2e-5
    )


def test_sfc_flash_band_table_has_no_masked_tasks():
    """The SFC attention kernel goes further than the guard: masked tiles
    are absent from its task table, so they cost no grid step at all —
    and the same NaN probe passes through the band scheduler."""
    from repro.core.attention_backend import flash_attention as sfc_flash

    b, s, h, d, chunk = 1, 64, 2, 16, 16
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = np.asarray(rng.normal(size=(b, s, h, d)), np.float32)
    v = np.asarray(rng.normal(size=(b, s, h, d)), np.float32)
    k[:, -chunk:] = np.nan
    v[:, -chunk:] = np.nan
    got = sfc_flash(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
        causal=True, q_chunk=chunk, k_chunk=chunk,
    )
    assert np.isfinite(np.asarray(got)[:, : s - chunk]).all()
