"""Pallas SFC-CA GEMM kernel: shape/dtype sweeps vs the pure-jnp oracle
(interpret mode on CPU), plus the Listing-1 reference algorithm."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.sfc_gemm import sfc_ca_gemm_reference
from repro.kernels.ops import pick_blocks, sfc_matmul
from repro.kernels.ref import add_reduce_ref, matmul_ref, partial_k_matmul_ref
from repro.kernels.sfc_gemm import add_reduce_pallas, build_task_table, sfc_gemm_pallas

def _mats(m, n, k, dtype):
    rng = np.random.default_rng([m, n, k, np.dtype(dtype).itemsize])
    a = jnp.asarray(rng.normal(size=(m, k)), dtype)
    b = jnp.asarray(rng.normal(size=(k, n)), dtype)
    return a, b


SHAPES = [
    # (m, n, k, bm, bn, k_layers, kbf)
    (32, 32, 32, 16, 16, 1, 1),
    (64, 32, 64, 16, 16, 2, 1),
    (32, 64, 128, 16, 16, 1, 4),
    (64, 64, 64, 32, 32, 2, 2),
    (128, 32, 64, 16, 16, 4, 1),
    (48, 80, 96, 16, 16, 2, 3),  # non-square, non-pow2 grid
]


@pytest.mark.parametrize("m,n,k,bm,bn,kl,kbf", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sfc_gemm_pallas_sweep(m, n, k, bm, bn, kl, kbf, dtype):
    a, b = _mats(m, n, k, dtype)
    got = sfc_matmul(a, b, bm=bm, bn=bn, k_layers=kl, k_block_factor=kbf, interpret=True)
    want = matmul_ref(a, b)
    tol = 2e-5 if dtype == jnp.float32 else 6e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


def test_partial_copies_match_k_slabs():
    """The (K_layers, M, N) replicated-C stage equals per-slab products."""
    a, b = _mats(32, 32, 64, jnp.float32)
    copies = sfc_gemm_pallas(a, b, bm=16, bn=16, k_layers=2, interpret=True)
    want = partial_k_matmul_ref(a, b, 2)
    np.testing.assert_allclose(np.asarray(copies), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_add_reduce_kernel():
    rng = np.random.default_rng(7)
    c = jnp.asarray(rng.normal(size=(4, 32, 48)), jnp.float32)
    got = add_reduce_pallas(c, bm=16, bn=16, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(add_reduce_ref(c)), rtol=1e-6)


def test_task_table_is_listing1_order():
    """Task t = layer-major, gilbert order within layer (Listing 1 12-14)."""
    tab = build_task_table(4, 4, 2)
    assert tab.shape == (3, 32)
    assert (tab[2, :16] == 0).all() and (tab[2, 16:] == 1).all()
    assert (tab[:2, :16] == tab[:2, 16:]).all()  # same SFC order per layer
    steps = np.abs(np.diff(tab[0, :16])) + np.abs(np.diff(tab[1, :16]))
    assert (steps == 1).all()  # gilbert adjacency


@given(
    m=st.integers(2, 9).map(lambda e: 2**e // 2 * 2),
    n=st.integers(8, 96),
    k=st.integers(8, 96),
)
@settings(max_examples=12, deadline=None)
def test_sfc_matmul_arbitrary_shapes_padding(m, n, k):
    """Arbitrary (non-divisible) shapes via zero padding."""
    a, b = _mats(m, n, k, jnp.float32)
    got = sfc_matmul(a, b, bm=16, bn=16, k_layers=1, k_block_factor=1, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(a) @ np.asarray(b), rtol=3e-5, atol=3e-5
    )


def test_reference_matches_oracle_knob_grid():
    """Listing-1 reference across the paper's (K_layers, kbf) knob grid."""
    a, b = _mats(64, 64, 128, jnp.float32)
    want = np.asarray(a) @ np.asarray(b)
    for kl in (1, 2, 4):
        for kbf in (1, 2):
            got = sfc_ca_gemm_reference(
                a, b, bm=16, bn=16, bk=16, k_layers=kl, k_block_factor=kbf
            )
            np.testing.assert_allclose(np.asarray(got), want, rtol=2e-5, atol=2e-5)


def test_pick_blocks_mxu_alignment():
    assert pick_blocks(1024, 2048, 512) == (256, 256)
    assert pick_blocks(48, 80, 96)[0] in (16, 48)


@pytest.mark.parametrize(
    "b,s,t,h,hkv,d,causal",
    [
        (2, 64, 64, 4, 2, 16, True),
        (1, 96, 96, 2, 2, 32, True),
        (2, 48, 48, 4, 1, 16, False),
        (1, 40, 72, 2, 2, 16, True),
        (2, 33, 50, 2, 1, 16, True),  # non-divisible: padding path
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, s, t, h, hkv, d, causal, dtype):
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.ref import flash_attention_ref

    rng = np.random.default_rng(s + t + h)
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, t, hkv, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, t, hkv, d)), dtype)
    got = flash_attention(q, k, v, causal=causal, q_chunk=16, k_chunk=16, interpret=True)
    want = flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 6e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )
