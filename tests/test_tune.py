"""Empirical tuner + persistent knob cache: round-trip, hit-skips-measure,
candidate generation, and the `sfc_matmul` cache consult."""

import numpy as np
import pytest

import repro.tune.tuner as tuner_mod
from repro.tune import KnobCache, Knobs, shape_bucket, tune_gemm
from repro.tune.cache import default_cache_path


@pytest.fixture
def cache(tmp_path):
    return KnobCache(str(tmp_path / "knobs.json"))


def test_shape_bucket_pow2_rounding():
    assert shape_bucket(1000, 1024, 1) == (1024, 1024, 1)
    assert shape_bucket(1025, 513, 48) == (2048, 1024, 64)


def test_cache_round_trip_across_instances(cache, tmp_path):
    k = Knobs(bm=64, bn=128, k_layers=2, k_block_factor=4,
              source="measured", time_s=1e-3)
    cache.put(1000, 512, 256, np.float32, "cpu", k)
    # same-bucket shapes hit, different buckets/dtypes/backends miss
    got = cache.get(780, 500, 200, np.float32, "cpu")
    assert got is not None and (got.bm, got.bn) == (64, 128)
    assert got.source == "cached"
    import jax.numpy as jnp

    assert cache.get(1000, 512, 256, jnp.bfloat16, "cpu") is None
    assert cache.get(1000, 512, 256, np.float32, "tpu") is None
    assert cache.get(3000, 512, 256, np.float32, "cpu") is None
    # a fresh instance reads the persisted file
    fresh = KnobCache(str(tmp_path / "knobs.json"))
    got2 = fresh.get(1024, 512, 256, np.float32, "cpu")
    assert got2 is not None and got2.k_block_factor == 4


def test_cache_survives_corrupt_file(tmp_path):
    p = tmp_path / "broken.json"
    p.write_text("{not json")
    c = KnobCache(str(p))
    assert c.get(64, 64, 64, np.float32, "cpu") is None
    c.put(64, 64, 64, np.float32, "cpu", Knobs(16, 16, 1, 1))
    assert KnobCache(str(p)).get(64, 64, 64, np.float32, "cpu") is not None


def test_candidate_knobs_seeded_by_analytical():
    cands = tuner_mod.candidate_knobs(256, 256, 512)
    assert len(cands) >= 2
    # the seed (analytical pick) is always first
    from repro.kernels.ops import pick_blocks

    assert (cands[0].bm, cands[0].bn) == pick_blocks(256, 256, 512)[:2]
    assert len({(c.bm, c.bn, c.k_layers, c.k_block_factor) for c in cands}) == len(cands)


def test_tune_measures_once_then_hits_cache(cache):
    calls = []

    def fake_measure(m, n, k, dtype, knobs):
        calls.append(knobs)
        # prefer the largest bm so the winner is deterministic
        return 1.0 / knobs.bm

    first = tune_gemm(96, 96, 96, np.float32, cache=cache, measure_fn=fake_measure)
    assert first.source == "measured"
    assert calls, "cold tune must measure"
    assert first.bm == max(c.bm for c in calls)  # argmin of fake_measure
    n_cold = len(calls)

    second = tune_gemm(96, 96, 96, np.float32, cache=cache, measure_fn=fake_measure)
    assert len(calls) == n_cold, "cache hit must not re-measure"
    assert second.source == "cached"
    assert (second.bm, second.bn) == (first.bm, first.bn)

    # same bucket, different shape: still a hit
    tune_gemm(90, 70, 80, np.float32, cache=cache, measure_fn=fake_measure)
    assert len(calls) == n_cold

    # force re-tunes
    tune_gemm(96, 96, 96, np.float32, cache=cache, measure_fn=fake_measure, force=True)
    assert len(calls) > n_cold


def test_tune_survives_failing_measurements(cache):
    def bad_measure(m, n, k, dtype, knobs):
        raise RuntimeError("no hardware")

    knobs = tune_gemm(64, 64, 64, np.float32, cache=cache, measure_fn=bad_measure)
    assert knobs.source == "analytical"  # falls back to the model seed
    # and the fallback is still cached
    assert cache.get(64, 64, 64, np.float32, tuner_mod._backend_name()) is not None


def test_sfc_matmul_consults_tune_cache(tmp_path, monkeypatch):
    """A measured winner in the default cache fills sfc_matmul's knobs."""
    import jax.numpy as jnp

    import repro.kernels.ops as ops

    path = str(tmp_path / "consult.json")
    monkeypatch.setenv("REPRO_SFC_TUNE_CACHE", path)
    monkeypatch.setattr(tuner_mod, "_DEFAULT_CACHE", None)  # re-read env
    cache = KnobCache(path)
    cache.put(
        32, 32, 32, jnp.float32, tuner_mod._backend_name(),
        Knobs(bm=8, bn=8, k_layers=1, k_block_factor=2, source="measured"),
    )

    seen = {}
    real = ops.sfc_gemm_fused

    def spy(a, b, *args, **kw):
        seen.update(kw)
        return real(a, b, *args, **kw)

    monkeypatch.setattr(ops, "sfc_gemm_fused", spy)
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(32, 32)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(32, 32)), jnp.float32)
    got = ops.sfc_matmul(a, b, interpret=True)
    assert (seen["bm"], seen["bn"], seen["k_block_factor"]) == (8, 8, 2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(a @ b), rtol=3e-5, atol=3e-5)


def test_default_cache_path_env(monkeypatch):
    monkeypatch.setenv("REPRO_SFC_TUNE_CACHE", "/tmp/some/cache.json")
    assert default_cache_path() == "/tmp/some/cache.json"


def test_device_keyed_lookup_with_legacy_fallback(tmp_path):
    """Entries written before device keying are still honoured, but a
    device-keyed write wins for its own device kind only."""
    path = str(tmp_path / "dev.json")
    legacy = KnobCache(path, device="")  # pre-device-keying writer
    legacy.put(64, 64, 64, np.float32, "cpu",
               Knobs(16, 16, 1, 1, source="measured"))

    v5e = KnobCache(path, device="tpu_v5e")
    hit = v5e.get(64, 64, 64, np.float32, "cpu")
    assert hit is not None and hit.bm == 16  # legacy fallback

    v5e.put(64, 64, 64, np.float32, "cpu",
            Knobs(32, 32, 1, 1, source="measured"))
    assert v5e.get(64, 64, 64, np.float32, "cpu").bm == 32
    # the legacy entry is untouched, and another device kind sees it —
    # not the v5e winner
    assert KnobCache(path, device="").get(64, 64, 64, np.float32, "cpu").bm == 16
    assert KnobCache(path, device="tpu_v4").get(64, 64, 64, np.float32, "cpu").bm == 16


def test_concurrent_writers_merge_not_clobber(tmp_path):
    """Parallel processes writing disjoint entries to one cache file must
    all survive (the advisory-locked read-merge-replace in `_save`)."""
    import os
    import subprocess
    import sys

    path = str(tmp_path / "shared.json")
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src")
    script = (
        "import sys, numpy as np\n"
        "from repro.tune import KnobCache, Knobs\n"
        "wid = int(sys.argv[1]); path = sys.argv[2]\n"
        "c = KnobCache(path, device='test_dev')\n"
        "for j in range(5):\n"
        "    c.put(64, 64, 64, np.float32, 'cpu',\n"
        "          Knobs(16, 16, 1, 1, source='measured'),\n"
        "          op=f'op{wid}_{j}')\n"
    )
    procs = [
        subprocess.Popen([sys.executable, "-c", script, str(i), path], env=env)
        for i in range(4)
    ]
    for p in procs:
        assert p.wait(timeout=120) == 0
    final = KnobCache(path, device="test_dev")
    for i in range(4):
        for j in range(5):
            assert final.get(64, 64, 64, np.float32, "cpu", op=f"op{i}_{j}") \
                is not None, f"lost op{i}_{j}"


def test_corrupt_cache_quarantined_to_sidecar(tmp_path):
    import json
    import warnings

    p = tmp_path / "knobs.json"
    p.write_text("{truncated json")
    c = KnobCache(str(p))
    with pytest.warns(RuntimeWarning, match="corrupt"):
        assert c.get(64, 64, 64, np.float32, "cpu") is None
    # the broken bytes are preserved for forensics, not deleted
    quarantined = list(tmp_path.glob("knobs.json.corrupt-*"))
    assert len(quarantined) == 1
    assert quarantined[0].read_text() == "{truncated json"
    # the cache rebuilds cleanly in place
    c.put(64, 64, 64, np.float32, "cpu", Knobs(16, 16, 1, 1))
    assert KnobCache(str(p)).get(64, 64, 64, np.float32, "cpu") is not None
    json.loads(p.read_text())  # and the new file is valid JSON
    # warn-once per path: a second corruption of the same file is silent
    p.write_text("{also bad")
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        KnobCache(str(p)).get(64, 64, 64, np.float32, "cpu")
    assert not [w for w in caught if "corrupt" in str(w.message)]


def test_stale_kernel_version_purges_entries(tmp_path):
    import json

    from repro.tune.cache import META_KEY, current_kernel_version

    p = tmp_path / "knobs.json"
    KnobCache(str(p)).put(64, 64, 64, np.float32, "cpu", Knobs(16, 16, 1, 1))
    raw = json.loads(p.read_text())
    assert raw[META_KEY]["kernel_version"] == current_kernel_version()
    # stamp the file as written by a different kernel generation
    raw[META_KEY] = {"kernel_version": current_kernel_version() + 1}
    p.write_text(json.dumps(raw))
    with pytest.warns(RuntimeWarning, match="kernel"):
        assert KnobCache(str(p)).get(64, 64, 64, np.float32, "cpu") is None
    # legacy files without a stamp stay valid (no retroactive purge)
    del raw[META_KEY]
    p.write_text(json.dumps(raw))
    assert KnobCache(str(p)).get(64, 64, 64, np.float32, "cpu") is not None


def test_save_does_not_resurrect_stale_on_disk_entries(tmp_path):
    import json

    from repro.tune.cache import META_KEY, current_kernel_version

    p = tmp_path / "knobs.json"
    a = KnobCache(str(p))
    a.put(64, 64, 64, np.float32, "cpu", Knobs(16, 16, 1, 1))
    # another process persisted an extra winner, then the file got stamped
    # as a stale kernel generation
    KnobCache(str(p)).put(128, 128, 128, np.float32, "cpu", Knobs(32, 32, 1, 1))
    raw = json.loads(p.read_text())
    raw[META_KEY] = {"kernel_version": current_kernel_version() + 7}
    p.write_text(json.dumps(raw))
    # a's next save merges with the on-disk file — but must refuse to
    # resurrect entries measured against different kernels
    a.put(256, 256, 256, np.float32, "cpu", Knobs(64, 64, 1, 1))
    fresh = KnobCache(str(p))
    assert fresh.get(64, 64, 64, np.float32, "cpu") is not None
    assert fresh.get(256, 256, 256, np.float32, "cpu") is not None
    assert fresh.get(128, 128, 128, np.float32, "cpu") is None
    assert (
        json.loads(p.read_text())[META_KEY]["kernel_version"]
        == current_kernel_version()
    )


def test_retune_lifts_ladder_quarantine(cache):
    from repro.robust import get_registry

    reg = get_registry()
    reg.quarantine("gemm", "sfc_pallas", None, "compile")
    reg.quarantine("glu", "sfc_pallas", None, "compile")
    assert "gemm" in reg.quarantined_namespaces()
    tune_gemm(
        64, 64, 64, np.float32,
        cache=cache, measure_fn=lambda m, n, k, d, kn: 1e-3,
    )
    # the measured winner vouches for the gemm path again — and only it
    assert "gemm" not in reg.quarantined_namespaces()
    assert "glu" in reg.quarantined_namespaces()
