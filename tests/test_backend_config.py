"""BackendConfig: the collapsed backend-selection API of
`make_train_step` / `make_eval_step`.

Covers the satellite contract: the legacy per-kwarg spellings
(``gemm_backend=``, ``attn_impl=``, ``fused_optimizer=``,
``stochastic_round=``) still build an identical step but emit a
``DeprecationWarning``, and mixing them with ``backend=`` is rejected.
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gemm_backend as gb
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.train.step import BackendConfig, make_eval_step, make_train_step


def _rand(*shape, seed=0):
    return jnp.asarray(
        np.random.RandomState(seed).randn(*shape).astype(np.float32)
    )


class _MiniModel:
    def init(self, key):
        k1, k2 = jax.random.split(key)
        return {
            "w1": (jax.random.normal(k1, (16, 32)) * 0.1).astype(jnp.float32),
            "w2": (jax.random.normal(k2, (32, 8)) * 0.1).astype(jnp.float32),
        }

    def loss(self, params, batch, *, remat="none"):
        h = gb.matmul(batch["x"], params["w1"], activation="gelu")
        y = gb.matmul(h, params["w2"])
        return jnp.mean((y - batch["y"]) ** 2)


@pytest.fixture()
def mini():
    model = _MiniModel()
    params = model.init(jax.random.PRNGKey(0))
    batch = {"x": _rand(6, 16, seed=3), "y": _rand(6, 8, seed=4)}
    return model, params, batch


def _bitwise(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert np.asarray(la).tobytes() == np.asarray(lb).tobytes()


def test_legacy_train_kwargs_warn_and_match_config(mini):
    model, params, batch = mini
    cfg = AdamWConfig(lr=1e-2)
    with pytest.warns(DeprecationWarning, match="make_train_step"):
        legacy = make_train_step(
            model, cfg, remat="none", gemm_backend="sfc_pallas"
        )
    new = make_train_step(
        model, cfg, remat="none",
        backend=BackendConfig(gemm_backend="sfc_pallas"),
    )
    p_l, s_l, m_l = legacy(params, adamw_init(params), batch)
    p_n, s_n, m_n = new(params, adamw_init(params), batch)
    _bitwise(p_l, p_n)
    _bitwise(s_l, s_n)
    assert float(m_l["loss"]) == float(m_n["loss"])


def test_new_style_does_not_warn(mini):
    model, params, batch = mini
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        step = make_train_step(
            model, AdamWConfig(lr=1e-2), remat="none",
            backend=BackendConfig(gemm_backend="xla"),
        )
        make_eval_step(model, backend=BackendConfig(gemm_backend="xla"))
    step(params, adamw_init(params), batch)


def test_mixing_backend_and_legacy_rejected(mini):
    model, _, _ = mini
    with pytest.raises(ValueError, match="not both"):
        make_train_step(
            model, AdamWConfig(), backend=BackendConfig(), gemm_backend="xla"
        )
    with pytest.raises(ValueError, match="not both"):
        make_eval_step(
            model, backend=BackendConfig(), attn_impl="blockwise"
        )


def test_legacy_eval_kwargs_warn_and_match_config(mini):
    model, params, batch = mini
    with pytest.warns(DeprecationWarning, match="make_eval_step"):
        legacy = make_eval_step(model, gemm_backend="sfc_pallas")
    new = make_eval_step(
        model, backend=BackendConfig(gemm_backend="sfc_pallas")
    )
    assert float(legacy(params, batch)) == float(new(params, batch))


def test_legacy_fused_kwarg_reaches_config(mini):
    # the deprecated fused_optimizer= still lands in the config — the
    # microbatch guard (which reads cfg.fused_optimizer) fires
    model, _, _ = mini
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="microbatches=1"):
            make_train_step(
                model, AdamWConfig(), fused_optimizer=True, microbatches=2
            )


def test_backend_config_is_frozen_and_hashable():
    cfg = BackendConfig(gemm_backend="sfc_pallas", attn_impl="sfc")
    with pytest.raises(Exception):
        cfg.gemm_backend = "xla"
    assert hash(cfg) == hash(
        BackendConfig(gemm_backend="sfc_pallas", attn_impl="sfc")
    )
