"""Performance-model tests: BRGEMM taxonomy, knob predictors, roofline."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hypothesis optional: property test skips, rest run
    given = settings = st = None

from repro.core.decomposition import sfc_decompose
from repro.core.perf_model import (
    TPU_V5E,
    NearestNeighborModel,
    analytical_time,
    choose_knobs_analytical,
    choose_knobs_autotune,
    gemm_flops,
    roofline_best_time,
    simulate_gemm,
    simulate_patch_traversal,
)


def test_brgemm_taxonomy_counts():
    """On a rectangular patch with infinite fast memory the SFC traversal
    fetches each A row-panel and B col-panel once: brgemm0+1+2 == rows+cols-1."""
    d = sfc_decompose(8, 8, 4, 1)
    p = d.patches[0]
    r = simulate_patch_traversal(
        p.cells, bm=128, bn=128, K=1024, k_layers=1, k_block_factor=1, hw=TPU_V5E
    )
    assert r.total == p.n_cells
    fetches = r.brgemm0 * 2 + r.brgemm1 + r.brgemm2
    assert fetches == p.n_rows + p.n_cols


def test_sfc_order_beats_row_major():
    """Paper Fig.-7 claim: in the realistic regime (fast memory holds a
    quadrant's panels but not a full row sweep's), SFC traversal moves
    several times fewer slow-memory bytes than row-major."""
    from repro.core.perf_model import HardwareModel

    hw = HardwareModel(
        name="cache32mb", gamma=1 / 197e12, beta=1 / 819e9, fast_bytes=32 * 2**20
    )
    d = sfc_decompose(32, 32, 1, 1)
    cells_sfc = d.patches[0].cells
    rows = np.repeat(np.arange(32), 32)
    cols = np.tile(np.arange(32), 32)
    cells_rm = np.stack([rows, cols], 1)
    kw = dict(bm=128, bn=128, K=8192, k_layers=1, k_block_factor=1, hw=hw)
    sfc = simulate_patch_traversal(cells_sfc, **kw)
    rm = simulate_patch_traversal(cells_rm, **kw)
    assert rm.slow_bytes / sfc.slow_bytes > 3.0  # measured ~5.9x
    assert sfc.time <= rm.time

    # and with cache >> working set both degenerate to compulsory misses
    big = simulate_patch_traversal(cells_sfc, **{**kw, "hw": TPU_V5E, "K": 1024})
    big_rm = simulate_patch_traversal(cells_rm, **{**kw, "hw": TPU_V5E, "K": 1024})
    assert big.slow_bytes == big_rm.slow_bytes


def test_replication_reduces_gemm_phase_bytes():
    """§II-C: larger c -> fewer words in the GEMM phase (before C reduce)."""
    r1 = simulate_gemm(4096, 4096, 4096, n_workers=64, k_layers=1)
    r4 = simulate_gemm(4096, 4096, 4096, n_workers=64, k_layers=4)
    assert r4["slow_bytes_total"] < r1["slow_bytes_total"]


def test_analytical_vs_simulator_agree_on_ranking():
    """The closed-form model must rank configurations like the simulator
    (paper: predictors land within a few % of autotuned)."""
    M = N = K = 4096
    best_sim, sweep = choose_knobs_autotune(M, N, K, 256)
    c_an, kbf_an = choose_knobs_analytical(M, N, K, 256)
    t_best = sweep[best_sim]
    t_an = sweep.get((c_an, kbf_an), np.inf)
    assert t_an <= t_best * 1.15  # within 15% of exhaustive


def test_nn_model_predicts_trained_point():
    shapes = [(1024, 1024, 1024), (4096, 4096, 4096), (8192, 1024, 2048)]
    nn = NearestNeighborModel().fit_autotuned(shapes, 64)
    best, _ = choose_knobs_autotune(4096, 4096, 4096, 64)
    assert nn.predict(4096, 4096, 4096) == best
    assert nn.predict(4000, 4100, 4096) == best  # nearest neighbour


def test_roofline_never_exceeds_peak():
    t, (tm, tn, c) = roofline_best_time(8192, 8192, 8192, 256)
    tflops = gemm_flops(8192, 8192, 8192) / t
    assert tflops <= 256 * TPU_V5E.peak_flops * 1.0001
    assert tm * tn * c == 256


def _check_throughput_bounded(m, n, k):
    best, sweep = choose_knobs_autotune(m, n, k, 64)
    t_roof, _ = roofline_best_time(m, n, k, 64)
    # simulator can't beat the infinite-memory roofline by more than noise
    assert min(sweep.values()) >= t_roof * 0.8


@pytest.mark.parametrize("m,n,k", [(512, 512, 512), (2048, 1024, 4096)])
def test_simulated_throughput_bounded_smoke(m, n, k):
    """Hypothesis-free sample of the roofline-bound property."""
    _check_throughput_bounded(m, n, k)


if st is None:

    def test_roofline_property_needs_hypothesis():
        pytest.importorskip("hypothesis")  # visible skip, not silent drop

else:

    @given(
        st.sampled_from([512, 1024, 2048, 4096]),
        st.sampled_from([512, 1024, 2048, 4096]),
        st.sampled_from([512, 1024, 2048, 4096]),
    )
    @settings(max_examples=10, deadline=None)
    def test_simulated_throughput_bounded_by_roofline(m, n, k):
        _check_throughput_bounded(m, n, k)
