"""Per-architecture smoke tests: reduced same-family config, one forward /
train step on CPU, shape + finiteness assertions; plus sequence-mixer
equivalence tests (chunked == sequential) and decode continuity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.registry import abstract_batch, build_model, input_specs
from repro.configs.base import SHAPES


def _batch_for(cfg, key, b=2, s=32):
    ks = jax.random.split(key, 4)
    batch = {
        "tokens": jax.random.randint(ks[0], (b, s), 0, cfg.vocab),
        "labels": jax.random.randint(ks[1], (b, s), 0, cfg.vocab),
    }
    if cfg.family == "audio":
        batch["src_embeds"] = jax.random.normal(ks[2], (b, s, cfg.d_model)) * 0.1
    if cfg.family == "vlm":
        batch["mrope_positions"] = jnp.broadcast_to(
            jnp.arange(s)[None, None], (3, b, s)
        ).astype(jnp.int32)
        batch["vision_embeds"] = jax.random.normal(ks[3], (b, 8, cfg.d_model)) * 0.1
    return batch


# per-arch grad-graph compiles dominate tier-1 wall-clock; the heaviest
# stacks and same-family config variants (yi/qwen2 are dense-transformer
# siblings of qwen3_4b/stablelm) run under --runslow — their prefill/decode
# smoke below still runs everywhere
_SLOW_TRAIN_SMOKE = {
    "xlstm_1_3b",
    "zamba2_1_2b",
    "seamless_m4t_medium",
    "yi_6b",
    "qwen2_72b",
}


def _arch_params(slow_set):
    return [
        pytest.param(a, marks=pytest.mark.slow) if a in slow_set else a
        for a in ARCH_IDS
    ]


@pytest.mark.parametrize("arch", _arch_params(_SLOW_TRAIN_SMOKE))
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = _batch_for(cfg, key)

    loss, grads = jax.jit(
        lambda p, b: jax.value_and_grad(lambda q: model.loss(q, b, remat="none"))(p)
    )(params, batch)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


# zamba2's fast-tier coverage is the (strictly stronger) prefill/decode
# consistency test below; its standalone smoke runs under --runslow
@pytest.mark.parametrize("arch", _arch_params({"zamba2_1_2b"}))
def test_smoke_prefill_decode(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    b, s = 2, 16
    batch = _batch_for(cfg, key, b, s)
    if cfg.family == "audio":
        logits, cache = model.prefill(
            params, batch["tokens"], batch["src_embeds"], cache_len=s + 4
        )
    elif cfg.family == "vlm":
        logits, cache = model.prefill(
            params,
            batch["tokens"],
            cache_len=s + 4,
            mrope_positions=batch["mrope_positions"],
            vision_embeds=batch["vision_embeds"],
        )
    else:
        logits, cache = model.prefill(params, batch["tokens"], cache_len=s + 4)
    assert logits.shape == (b, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    tok = jnp.argmax(logits, -1)[:, None]
    kw = {}
    if cfg.family == "vlm":
        kw["mrope_positions"] = jnp.full((3, b, 1), s, jnp.int32)
    logits2, cache2 = model.decode_step(params, tok, cache, **kw)
    assert logits2.shape == (b, cfg.vocab)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


@pytest.mark.parametrize(
    "arch",
    ["yi_6b", "zamba2_1_2b", pytest.param("xlstm_1_3b", marks=pytest.mark.slow)],
)
def test_prefill_decode_consistency_with_forward(arch):
    """Greedy decode after prefill == argmax of teacher-forced forward."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    b, s = 2, 20
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab)

    logits_full, _ = model.forward(params, toks, remat="none")
    logits_pre, cache = model.prefill(params, toks[:, : s - 1], cache_len=s + 2)
    np.testing.assert_allclose(
        np.asarray(logits_pre, np.float32),
        np.asarray(logits_full[:, s - 2], np.float32),
        rtol=3e-3,
        atol=3e-3,
    )
    logits_dec, _ = model.decode_step(params, toks[:, s - 1 :], cache)
    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32),
        np.asarray(logits_full[:, s - 1], np.float32),
        rtol=3e-3,
        atol=3e-3,
    )


def test_mamba2_chunked_matches_sequential():
    from repro.models.ssm import ssd_chunked, ssd_decode_step

    B, S, H, P, N = 2, 13, 3, 8, 8
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    xs = jax.random.normal(ks[0], (B, S, H, P)) * 0.3
    bm = jax.random.normal(ks[1], (B, S, N)) * 0.3
    cm = jax.random.normal(ks[2], (B, S, N)) * 0.3
    la = -jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))
    st = jnp.zeros((B, H, N, P))
    outs = []
    for t in range(S):
        st, y = ssd_decode_step(st, xs[:, t], bm[:, t], cm[:, t], la[:, t])
        outs.append(y)
    want = jnp.stack(outs, 1)
    for chunk in (5, 13):
        got = ssd_chunked(xs, bm, cm, la, chunk=chunk)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_mlstm_chunked_matches_sequential():
    from repro.models.xlstm import mlstm_chunked, mlstm_decode_step

    B, S, H, P = 2, 13, 3, 8
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    q, k, v = (jax.random.normal(ks[i], (B, S, H, P)) * 0.5 for i in range(3))
    ig = jax.random.normal(ks[3], (B, S, H)) * 2.0
    fg = jax.random.normal(ks[4], (B, S, H)) * 2.0 + 2.0
    st = (
        jnp.zeros((B, H, P, P)),
        jnp.zeros((B, H, P)),
        jnp.full((B, H), -1e30),
    )
    outs = []
    for t in range(S):
        st, h = mlstm_decode_step(st, q[:, t], k[:, t], v[:, t], ig[:, t], fg[:, t])
        outs.append(h)
    want = jnp.stack(outs, 1)
    for chunk in (5, 13):
        got = mlstm_chunked(q, k, v, ig, fg, chunk=chunk)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_blockwise_attention_matches_dense():
    from repro.models.layers import blockwise_attention

    B, S, H, HKV, D = 2, 37, 4, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, HKV, D))
    v = jax.random.normal(ks[2], (B, S, HKV, D))

    # dense oracle
    kk = jnp.repeat(k, H // HKV, axis=2)
    vv = jnp.repeat(v, H // HKV, axis=2)
    s_ = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / np.sqrt(D)
    mask = jnp.tril(jnp.ones((S, S), bool))
    s_ = jnp.where(mask[None, None], s_, -1e30)
    want = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s_, -1), vv)

    for qc, kc in ((8, 8), (16, 32), (64, 64)):
        got = blockwise_attention(q, k, v, causal=True, q_chunk=qc, k_chunk=kc)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_moe_matches_per_token_oracle():
    from repro.models.moe import moe_forward, moe_init

    key = jax.random.PRNGKey(5)
    p = moe_init(key, d_model=16, d_ff=32, n_experts=4, dtype=jnp.float32)
    x = jax.random.normal(key, (2, 8, 16)) * 0.5
    out, aux = moe_forward(p, x, top_k=2, capacity_factor=4.0)
    xt = np.asarray(x.reshape(-1, 16))
    logits = xt @ np.asarray(p["router"])
    probs = np.exp(logits) / np.exp(logits).sum(-1, keepdims=True)
    top = np.argsort(-probs, axis=-1)[:, :2]
    want = np.zeros_like(xt)
    for t in range(16):
        g = probs[t, top[t]]
        g = g / g.sum()
        for j, e in enumerate(top[t]):
            h = xt[t] @ np.asarray(p["w_in"][e])
            gt = xt[t] @ np.asarray(p["w_gate"][e])
            h = (gt / (1 + np.exp(-gt))) * h
            want[t] += g[j] * (h @ np.asarray(p["w_out"][e]))
    np.testing.assert_allclose(np.asarray(out).reshape(-1, 16), want, rtol=2e-4, atol=2e-4)


def test_input_specs_cover_all_cells():
    """Every non-skipped (arch x shape) cell has well-formed abstract inputs."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            if shape.name == "long_500k" and not cfg.subquadratic:
                continue
            spec = input_specs(cfg, shape)
            leaves = jax.tree.leaves(spec)
            assert leaves, (arch, shape.name)
            for l in leaves:
                assert all(d > 0 for d in l.shape)


def test_flash_pallas_attn_impl_equivalence():
    """The selectable flash_pallas attention implementation (Pallas kernel,
    interpret on CPU) matches the default blockwise path end to end."""
    import dataclasses

    cfg = get_config("yi_6b").reduced()
    m1 = build_model(cfg)
    m2 = build_model(dataclasses.replace(cfg, attn_impl="flash_pallas"))
    params = m1.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    l1, _ = m1.forward(params, toks, remat="none")
    l2, _ = m2.forward(params, toks, remat="none")
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=2e-4, atol=2e-4)
