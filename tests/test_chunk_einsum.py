"""chunk_einsum: the schedule-compiler payoff — xLSTM/SSM chunked-recurrence
intra-chunk einsums routed through the SFC batched kernels with *no new
table code* (the task table, tune bucket and fallback ladder all derive
from the compiled `ScheduleSpec`).

Acceptance contract (ISSUE 8): the routed blocks match `jnp.einsum` at f32
rtol 1e-4, and under ``gemm_backend("sfc_pallas")`` their jaxpr contains
no `dot_general` — jaxpr-gated per signature and at the model level.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gemm_backend as gb
from repro.core import namespaces as ns

SIGNATURES = {
    "blhp,bjhp->bljh": ((2, 24, 3, 16), (2, 24, 3, 16)),
    "bljh,bjhp->blhp": ((2, 24, 24, 3), (2, 24, 3, 16)),
    "bcin,bcjn->bcij": ((2, 4, 24, 16), (2, 4, 24, 16)),
    "bcijh,bcjhp->bcihp": ((1, 2, 24, 24, 3), (1, 2, 24, 3, 16)),
}


def _operands(subs, seed=0):
    sa, sb = SIGNATURES[subs]
    rng = np.random.default_rng(seed)
    return (
        jnp.asarray(rng.standard_normal(sa), jnp.float32),
        jnp.asarray(rng.standard_normal(sb), jnp.float32),
    )


def _census(jaxpr, counts):
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            counts["pallas"] += 1
            continue
        if eqn.primitive.name == "dot_general":
            counts["dot"] += 1
        for val in eqn.params.values():
            _census_param(val, counts)
    return counts


def _census_param(val, counts):
    if isinstance(val, jax.core.ClosedJaxpr):
        _census(val.jaxpr, counts)
    elif isinstance(val, jax.core.Jaxpr):
        _census(val, counts)
    elif isinstance(val, (tuple, list)):
        for v in val:
            _census_param(v, counts)


def _count(fn, *args):
    jx = jax.make_jaxpr(fn)(*args)
    return _census(jx.jaxpr, {"dot": 0, "pallas": 0})


# ---------------------------------------------------------------------------
# per-signature: numerics, gradients, jaxpr gate
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("subs", sorted(SIGNATURES))
def test_chunk_einsum_matches_jnp(subs):
    a, b = _operands(subs)
    ref = jnp.einsum(subs, a, b, preferred_element_type=jnp.float32)
    with gb.gemm_backend("sfc_pallas"):
        got = gb.chunk_einsum(subs, a, b, preferred_element_type=jnp.float32)
    assert got.dtype == ref.dtype and got.shape == ref.shape
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref), rtol=1e-4, atol=1e-5
    )


@pytest.mark.parametrize("subs", sorted(SIGNATURES))
def test_chunk_einsum_is_dot_general_free(subs):
    a, b = _operands(subs)

    def routed(a, b):
        with gb.gemm_backend("sfc_pallas"):
            return gb.chunk_einsum(
                subs, a, b, preferred_element_type=jnp.float32
            )

    c = _count(routed, a, b)
    assert c["pallas"] > 0
    assert c["dot"] == 0, f"dot_general survived chunk_einsum({subs!r})"


def test_chunk_einsum_xla_backend_is_verbatim_einsum():
    subs = "blhp,bjhp->bljh"
    a, b = _operands(subs)
    with gb.gemm_backend("xla"):
        got = gb.chunk_einsum(subs, a, b, preferred_element_type=jnp.float32)
    ref = jnp.einsum(subs, a, b, preferred_element_type=jnp.float32)
    assert np.array_equal(np.asarray(got), np.asarray(ref))


def test_chunk_einsum_rejects_unknown_signature():
    a, b = _operands("bcin,bcjn->bcij")
    with pytest.raises(ValueError, match="registered signatures"):
        gb.chunk_einsum("bin,bjn->bij", a, b)


def test_chunk_einsum_grads_match_xla():
    subs = "blhp,bjhp->bljh"
    a, b = _operands(subs, seed=1)

    def loss(route):
        def f(a, b):
            if route:
                with gb.gemm_backend("sfc_pallas"):
                    y = gb.chunk_einsum(
                        subs, a, b, preferred_element_type=jnp.float32
                    )
            else:
                y = jnp.einsum(subs, a, b, preferred_element_type=jnp.float32)
            return jnp.sum(y**2)

        return f

    gs = jax.grad(loss(True), (0, 1))(a, b)
    gx = jax.grad(loss(False), (0, 1))(a, b)
    for s, x in zip(gs, gx):
        np.testing.assert_allclose(
            np.asarray(s), np.asarray(x), rtol=1e-4, atol=1e-5
        )


# ---------------------------------------------------------------------------
# schedule-derived identity: tune namespace + per-schedule ladder
# ---------------------------------------------------------------------------


def test_chunk_gemm_plan_namespace_is_schedule_qualified():
    from repro.kernels.ops import chunk_gemm_plan

    namespace, knobs = chunk_gemm_plan(24, 24, 16, jnp.float32)
    assert ns.is_schedule_namespace(namespace)
    assert ns.base_namespace(namespace) == ns.NS_GEMM
    base, key = namespace.split("@")
    assert len(key) == 12
    assert set(knobs) == {"bm", "bn", "k_layers", "k_block_factor"}
    # deterministic: the same tile space compiles to the same identity
    assert chunk_gemm_plan(24, 24, 16, jnp.float32)[0] == namespace
    # a different tile space is a different bucket (24x24 and 192x24 pad
    # to the *same* 3x3 grid, so they intentionally share one)
    assert chunk_gemm_plan(192, 24, 16, jnp.float32)[0] == namespace
    other, _ = chunk_gemm_plan(1024, 512, 64, jnp.float32)
    assert other != namespace and ns.base_namespace(other) == ns.NS_GEMM


def test_chunk_einsum_heals_per_schedule():
    from repro.robust import FaultSpec, fault_injection

    subs = "bcin,bcjn->bcij"
    a, b = _operands(subs)
    ref = jnp.einsum(subs, a, b, preferred_element_type=jnp.float32)
    with fault_injection(
        FaultSpec(f"{ns.NS_GEMM}@*", kind="compile")
    ) as state:
        with gb.gemm_backend("sfc_pallas"):
            got = gb.chunk_einsum(
                subs, a, b, preferred_element_type=jnp.float32
            )
    assert state.fired, "injected fault never matched the schedule namespace"
    assert all(ns.is_schedule_namespace(f[0]) for f in state.fired)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref), rtol=1e-4, atol=1e-5
    )


# ---------------------------------------------------------------------------
# model level: the xLSTM / SSD intra-chunk blocks route end-to-end
# ---------------------------------------------------------------------------


def _mlstm_inputs(seed=0):
    rng = np.random.default_rng(seed)
    b, s, h, p = 1, 48, 2, 16
    mk = lambda *shape: jnp.asarray(
        rng.standard_normal(shape) * 0.3, jnp.float32
    )
    return mk(b, s, h, p), mk(b, s, h, p), mk(b, s, h, p), mk(b, s, h), mk(b, s, h)


def _ssd_inputs(seed=0):
    rng = np.random.default_rng(seed)
    b, s, h, p, n = 1, 48, 2, 16, 8
    x = jnp.asarray(rng.standard_normal((b, s, h, p)) * 0.3, jnp.float32)
    bm = jnp.asarray(rng.standard_normal((b, s, n)) * 0.3, jnp.float32)
    cm = jnp.asarray(rng.standard_normal((b, s, n)) * 0.3, jnp.float32)
    la = -jnp.asarray(rng.uniform(0.01, 0.2, (b, s, h)), jnp.float32)
    return x, bm, cm, la


def test_mlstm_chunked_matches_xla_backend():
    from repro.models.xlstm import mlstm_chunked

    args = _mlstm_inputs()
    with gb.gemm_backend("xla"):
        ref = mlstm_chunked(*args, chunk=24)
    with gb.gemm_backend("sfc_pallas"):
        got = mlstm_chunked(*args, chunk=24)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref), rtol=1e-4, atol=1e-5
    )


def test_ssd_chunked_matches_xla_backend():
    from repro.models.ssm import ssd_chunked

    args = _ssd_inputs()
    with gb.gemm_backend("xla"):
        ref = ssd_chunked(*args, chunk=24)
    with gb.gemm_backend("sfc_pallas"):
        got = ssd_chunked(*args, chunk=24)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref), rtol=1e-4, atol=1e-5
    )


@pytest.mark.parametrize("which", ["mlstm", "ssd"])
def test_intra_chunk_blocks_are_jaxpr_gated(which):
    """The routed intra-chunk einsums (two per model) vanish from the
    dot_general census under sfc_pallas and reappear as pallas launches;
    the inter-chunk scan carries stay on XLA dots (not in scope)."""
    if which == "mlstm":
        from repro.models.xlstm import mlstm_chunked as fn
        args = _mlstm_inputs()
    else:
        from repro.models.ssm import ssd_chunked as fn
        args = _ssd_inputs()

    def run(backend):
        def wrapped(*a):
            with gb.gemm_backend(backend):
                return fn(*a, chunk=24)

        return _count(wrapped, *args)

    c_xla = run("xla")
    c_sfc = run("sfc_pallas")
    assert c_xla["pallas"] == 0
    assert c_sfc["pallas"] > 0, "no SFC kernel launched in the chunked scan"
    assert c_sfc["dot"] == c_xla["dot"] - 2, (
        "expected exactly the two intra-chunk einsums to leave the "
        f"dot_general census: xla={c_xla['dot']} sfc={c_sfc['dot']}"
    )
