"""Serving engine tests: continuous batching, backend equivalence, metrics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.gemm_backend import gemm_backend
from repro.models.registry import build_model
from repro.serving.engine import ServingEngine


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("qwen3_4b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_engine_batched_requests(small_model):
    cfg, model, params = small_model
    engine = ServingEngine(cfg, params, max_batch=3, max_seq=32)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=12).astype(np.int32) for _ in range(7)]
    reqs = engine.submit_many(prompts, max_new_tokens=6)
    done = engine.run(reqs)
    assert len(done) == 7
    for r in done:
        assert len(r.output) == 6
        assert r.done_at >= r.first_token_at >= r.submitted_at
    rep = engine.latency_report(done)
    assert rep["tokens_total"] == 42
    assert rep["tokens_per_s"] > 0


def test_engine_matches_manual_greedy(small_model):
    """Engine greedy output == manual prefill+decode loop."""
    cfg, model, params = small_model
    engine = ServingEngine(cfg, params, max_batch=1, max_seq=24)
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab, size=10).astype(np.int32)
    [req] = engine.submit_many([prompt], max_new_tokens=5)
    [done] = engine.run([req])

    logits, cache = model.prefill(params, jnp.asarray(prompt)[None], cache_len=24)
    want = [int(jnp.argmax(logits, -1)[0])]
    tok = jnp.argmax(logits, -1)[:, None]
    for _ in range(4):
        logits, cache = model.decode_step(params, tok, cache)
        tok = jnp.argmax(logits, -1)[:, None]
        want.append(int(tok[0, 0]))
    assert done.output == want


def test_backend_equivalence_through_serving(small_model):
    cfg, model, params = small_model
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab, size=8).astype(np.int32)
    outs = {}
    for backend in ("xla", "sfc_pallas"):
        engine = ServingEngine(cfg, params, max_batch=1, max_seq=16, gemm_backend=backend)
        [req] = engine.submit_many([prompt], max_new_tokens=4)
        [done] = engine.run([req])
        outs[backend] = done.output
    assert outs["xla"] == outs["sfc_pallas"]
