"""Serving engine tests: continuous batching, backend equivalence, metrics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.gemm_backend import gemm_backend
from repro.models.registry import build_model
from repro.serving.engine import ServingEngine


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("qwen3_4b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_engine_batched_requests(small_model):
    cfg, model, params = small_model
    engine = ServingEngine(cfg, params, max_batch=3, max_seq=32)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=12).astype(np.int32) for _ in range(7)]
    reqs = engine.submit_many(prompts, max_new_tokens=6)
    done = engine.run(reqs)
    assert len(done) == 7
    for r in done:
        assert len(r.output) == 6
        assert r.done_at >= r.first_token_at >= r.submitted_at
    rep = engine.latency_report(done)
    assert rep["tokens_total"] == 42
    assert rep["tokens_per_s"] > 0


def test_engine_matches_manual_greedy(small_model):
    """Engine greedy output == manual prefill+decode loop."""
    cfg, model, params = small_model
    engine = ServingEngine(cfg, params, max_batch=1, max_seq=24)
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab, size=10).astype(np.int32)
    [req] = engine.submit_many([prompt], max_new_tokens=5)
    [done] = engine.run([req])

    logits, cache = model.prefill(params, jnp.asarray(prompt)[None], cache_len=24)
    want = [int(jnp.argmax(logits, -1)[0])]
    tok = jnp.argmax(logits, -1)[:, None]
    for _ in range(4):
        logits, cache = model.decode_step(params, tok, cache)
        tok = jnp.argmax(logits, -1)[:, None]
        want.append(int(tok[0, 0]))
    assert done.output == want


def test_backend_equivalence_through_serving(small_model):
    cfg, model, params = small_model
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab, size=8).astype(np.int32)
    outs = {}
    for backend in ("xla", "sfc_pallas"):
        engine = ServingEngine(cfg, params, max_batch=1, max_seq=16, gemm_backend=backend)
        [req] = engine.submit_many([prompt], max_new_tokens=4)
        [done] = engine.run([req])
        outs[backend] = done.output
    assert outs["xla"] == outs["sfc_pallas"]


def test_deadline_sheds_waiting_and_retires_live(small_model):
    cfg, model, params = small_model
    engine = ServingEngine(cfg, params, max_batch=2, max_seq=32)
    rng = np.random.default_rng(3)
    prompts = [
        rng.integers(0, cfg.vocab, size=8).astype(np.int32) for _ in range(3)
    ]
    reqs = engine.submit_many(prompts, max_new_tokens=4, deadline_s=60.0)
    # one request "arrived" long ago: already past its budget when run()
    # starts, so it must be shed before any compute is spent on it
    reqs[1].submitted_at -= 120.0
    done = engine.run(reqs)
    assert len(done) == 3
    by_uid = {r.uid: r for r in done}
    shed = by_uid[reqs[1].uid]
    assert shed.status == "timed_out"
    assert shed.output == []
    assert shed.first_token_at == 0.0  # never prefillled
    for r in (by_uid[reqs[0].uid], by_uid[reqs[2].uid]):
        assert r.status == "completed"
        assert len(r.output) == 4
    rep = engine.latency_report(done)
    assert rep["n_requests"] == 3
    assert rep["n_timed_out"] == 1
    assert rep["tokens_total"] == 8
    assert rep["ttft_mean_s"] >= 0.0


def test_deadline_retires_mid_decode(small_model):
    cfg, model, params = small_model
    engine = ServingEngine(cfg, params, max_batch=1, max_seq=32)
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, cfg.vocab, size=8).astype(np.int32)
    # a generous deadline survives the whole decode
    [req] = engine.submit_many([prompt], max_new_tokens=16, deadline_s=1e9)
    done = engine.run([req])[0]
    assert done.status == "completed"
    # an expiring one: admitted fresh, then the budget burns away during
    # serving so a decode-boundary check retires it mid-generation
    [req2] = engine.submit_many([prompt], max_new_tokens=16)

    orig_decode = engine._decode

    def slow_decode(*args):
        req2.submitted_at -= 1.0  # burn the budget during serving
        return orig_decode(*args)

    engine._decode = slow_decode
    req2.deadline_s = 0.5
    done2 = engine.run([req2])[0]
    assert done2.status == "timed_out"
    assert 1 <= len(done2.output) < 16  # partial output kept
    rep = engine.latency_report([done2])
    assert rep["n_timed_out"] == 1


def test_latency_report_empty_is_zeros(small_model):
    cfg, model, params = small_model
    engine = ServingEngine(cfg, params, max_batch=1, max_seq=16)
    rep = engine.latency_report([])
    assert rep == {
        "n_requests": 0,
        "n_timed_out": 0,
        "ttft_mean_s": 0.0,
        "ttft_p50_s": 0.0,
        "ttft_p95_s": 0.0,
        "ttft_p99_s": 0.0,
        "latency_mean_s": 0.0,
        "token_p50_s": 0.0,
        "token_p95_s": 0.0,
        "token_p99_s": 0.0,
        "tokens_total": 0,
        "tokens_per_s": 0.0,
    }


def test_deadline_retires_at_prefill_boundary(small_model):
    """A budget that burns away *during* prefill retires the request at
    the prefill boundary — no first token, no decode compute — while its
    batchmates decode normally."""
    cfg, model, params = small_model
    engine = ServingEngine(cfg, params, max_batch=2, max_seq=32)
    rng = np.random.default_rng(7)
    prompts = [
        rng.integers(0, cfg.vocab, size=8).astype(np.int32) for _ in range(2)
    ]
    reqs = engine.submit_many(prompts, max_new_tokens=4)
    reqs[0].deadline_s = 0.5  # alive at admission...

    orig_prefill = engine._prefill

    def slow_prefill(*args):
        reqs[0].submitted_at -= 1.0  # ...but the budget burns inside prefill
        return orig_prefill(*args)

    engine._prefill = slow_prefill
    done = engine.run(reqs)
    by_uid = {r.uid: r for r in done}
    timed_out = by_uid[reqs[0].uid]
    assert timed_out.status == "timed_out"
    assert timed_out.output == []
    assert timed_out.first_token_at == 0.0
    ok = by_uid[reqs[1].uid]
    assert ok.status == "completed" and len(ok.output) == 4
    rep = engine.latency_report(done)
    assert rep["n_timed_out"] == 1
    assert rep["tokens_total"] == 4


def test_sampled_abft_verification_counts_and_matches(small_model):
    """verify_every=N runs every Nth decode step under abft="detect";
    a clean run verifies without perturbing outputs or counting SDC."""
    from repro.robust import reset_runtime_sdc

    cfg, model, params = small_model
    reset_runtime_sdc()
    rng = np.random.default_rng(8)
    prompt = rng.integers(0, cfg.vocab, size=8).astype(np.int32)

    base = ServingEngine(cfg, params, max_batch=1, max_seq=32,
                         gemm_backend="sfc_pallas")
    [r1] = base.submit_many([prompt], max_new_tokens=6)
    [d1] = base.run([r1])

    eng = ServingEngine(cfg, params, max_batch=1, max_seq=32,
                        gemm_backend="sfc_pallas", verify_every=2)
    [r2] = eng.submit_many([prompt], max_new_tokens=6)
    [d2] = eng.run([r2])

    assert d2.output == d1.output
    rep = eng.degradation_report()["verify"]
    assert rep == {
        "verify_every": 2,
        "decode_steps": 5,      # max_new_tokens - 1 loop iterations
        "verified_steps": 2,    # steps 2 and 4
        "sdc_detections": 0,
    }


def test_sampled_verification_detection_redoes_step(small_model):
    """A runtime SDC detection during a verified step quarantines the
    Pallas rungs, re-jits, and redoes the step — the request completes
    and the detection is ledgered."""
    from repro.robust import abft, get_registry

    cfg, model, params = small_model
    abft.reset_runtime_sdc()
    rng = np.random.default_rng(9)
    prompt = rng.integers(0, cfg.vocab, size=8).astype(np.int32)
    eng = ServingEngine(cfg, params, max_batch=1, max_seq=32,
                        verify_every=3)

    orig_verify = eng._decode_verify

    def corrupted_verify(params, token, cache):
        # model an in-kernel checksum mismatch surfacing via the runtime
        # counter mid-step (the jitted program cannot raise)
        abft._record_runtime_sdc("gemm", True, 1.0, 0.0)
        return orig_verify(params, token, cache)

    eng._decode_verify = corrupted_verify
    [req] = eng.submit_many([prompt], max_new_tokens=6)
    [done] = eng.run([req])

    assert done.status == "completed"
    assert len(done.output) == 6
    rep = eng.degradation_report()["verify"]
    # step 3 detected and was redone; the re-jit replaced the corrupted
    # wrapper, so step 6 (if verified) runs clean
    assert rep["sdc_detections"] == 1
    assert rep["verified_steps"] >= 1
    reg = get_registry()
    assert "gemm" in reg.quarantined_namespaces()
    assert {r["reason"] for r in reg.export_state().values()} == {"sdc"}
    abft.reset_runtime_sdc()
