"""Serving engine tests: continuous batching, backend equivalence, metrics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.gemm_backend import gemm_backend
from repro.models.registry import build_model
from repro.serving.engine import ServingEngine


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("qwen3_4b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_engine_batched_requests(small_model):
    cfg, model, params = small_model
    engine = ServingEngine(cfg, params, max_batch=3, max_seq=32)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=12).astype(np.int32) for _ in range(7)]
    reqs = engine.submit_many(prompts, max_new_tokens=6)
    done = engine.run(reqs)
    assert len(done) == 7
    for r in done:
        assert len(r.output) == 6
        assert r.done_at >= r.first_token_at >= r.submitted_at
    rep = engine.latency_report(done)
    assert rep["tokens_total"] == 42
    assert rep["tokens_per_s"] > 0


def test_engine_matches_manual_greedy(small_model):
    """Engine greedy output == manual prefill+decode loop."""
    cfg, model, params = small_model
    engine = ServingEngine(cfg, params, max_batch=1, max_seq=24)
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab, size=10).astype(np.int32)
    [req] = engine.submit_many([prompt], max_new_tokens=5)
    [done] = engine.run([req])

    logits, cache = model.prefill(params, jnp.asarray(prompt)[None], cache_len=24)
    want = [int(jnp.argmax(logits, -1)[0])]
    tok = jnp.argmax(logits, -1)[:, None]
    for _ in range(4):
        logits, cache = model.decode_step(params, tok, cache)
        tok = jnp.argmax(logits, -1)[:, None]
        want.append(int(tok[0, 0]))
    assert done.output == want


def test_backend_equivalence_through_serving(small_model):
    cfg, model, params = small_model
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab, size=8).astype(np.int32)
    outs = {}
    for backend in ("xla", "sfc_pallas"):
        engine = ServingEngine(cfg, params, max_batch=1, max_seq=16, gemm_backend=backend)
        [req] = engine.submit_many([prompt], max_new_tokens=4)
        [done] = engine.run([req])
        outs[backend] = done.output
    assert outs["xla"] == outs["sfc_pallas"]


def test_deadline_sheds_waiting_and_retires_live(small_model):
    cfg, model, params = small_model
    engine = ServingEngine(cfg, params, max_batch=2, max_seq=32)
    rng = np.random.default_rng(3)
    prompts = [
        rng.integers(0, cfg.vocab, size=8).astype(np.int32) for _ in range(3)
    ]
    reqs = engine.submit_many(prompts, max_new_tokens=4, deadline_s=60.0)
    # one request "arrived" long ago: already past its budget when run()
    # starts, so it must be shed before any compute is spent on it
    reqs[1].submitted_at -= 120.0
    done = engine.run(reqs)
    assert len(done) == 3
    by_uid = {r.uid: r for r in done}
    shed = by_uid[reqs[1].uid]
    assert shed.status == "timed_out"
    assert shed.output == []
    assert shed.first_token_at == 0.0  # never prefillled
    for r in (by_uid[reqs[0].uid], by_uid[reqs[2].uid]):
        assert r.status == "completed"
        assert len(r.output) == 4
    rep = engine.latency_report(done)
    assert rep["n_requests"] == 3
    assert rep["n_timed_out"] == 1
    assert rep["tokens_total"] == 8
    assert rep["ttft_mean_s"] >= 0.0


def test_deadline_retires_mid_decode(small_model):
    cfg, model, params = small_model
    engine = ServingEngine(cfg, params, max_batch=1, max_seq=32)
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, cfg.vocab, size=8).astype(np.int32)
    # a generous deadline survives the whole decode
    [req] = engine.submit_many([prompt], max_new_tokens=16, deadline_s=1e9)
    done = engine.run([req])[0]
    assert done.status == "completed"
    # an expiring one: admitted fresh, then the budget burns away during
    # serving so a decode-boundary check retires it mid-generation
    [req2] = engine.submit_many([prompt], max_new_tokens=16)

    orig_decode = engine._decode

    def slow_decode(*args):
        req2.submitted_at -= 1.0  # burn the budget during serving
        return orig_decode(*args)

    engine._decode = slow_decode
    req2.deadline_s = 0.5
    done2 = engine.run([req2])[0]
    assert done2.status == "timed_out"
    assert 1 <= len(done2.output) < 16  # partial output kept
    rep = engine.latency_report([done2])
    assert rep["n_timed_out"] == 1


def test_latency_report_empty_is_zeros(small_model):
    cfg, model, params = small_model
    engine = ServingEngine(cfg, params, max_batch=1, max_seq=16)
    rep = engine.latency_report([])
    assert rep == {
        "n_requests": 0,
        "n_timed_out": 0,
        "ttft_mean_s": 0.0,
        "latency_mean_s": 0.0,
        "tokens_total": 0,
        "tokens_per_s": 0.0,
    }
