"""End-to-end behaviour tests for the whole system."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.launch.train import build_trainer

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_training_reduces_loss_on_learnable_data():
    """A tiny dense LM must visibly learn the synthetic affine-recurrence
    stream within 40 steps (measured drop ~4.1 nats; threshold 0.5)."""
    cfg = get_config("stablelm_1_6b").reduced()
    params, opt, step, batch_fn = build_trainer(
        cfg, batch=8, seq=16, lr=2e-3, total_steps=40
    )
    first = None
    last = None
    for i in range(40):
        params, opt, m = step(params, opt, batch_fn(i))
        if i == 0:
            first = float(m["loss"])
        last = float(m["loss"])
    assert last < first - 0.5, (first, last)


def test_train_metrics_contract():
    cfg = get_config("olmoe_1b_7b").reduced()
    params, opt, step, batch_fn = build_trainer(cfg, batch=4, seq=16, total_steps=3)
    params, opt, m = step(params, opt, batch_fn(0))
    assert set(m) == {"loss", "grad_norm", "lr"}
    assert np.isfinite(float(m["grad_norm"]))


@pytest.mark.slow
def test_microbatched_step_matches_full_batch():
    """Grad accumulation must be loss/param-equivalent to the full batch."""
    cfg = get_config("yi_6b").reduced()
    p1, o1, s1, batch_fn = build_trainer(cfg, batch=8, seq=16, lr=1e-3, total_steps=4)
    p2, o2, s2, _ = build_trainer(
        cfg, batch=8, seq=16, lr=1e-3, total_steps=4, microbatches=4
    )
    b = batch_fn(0)
    p1, o1, m1 = s1(p1, o1, b)
    p2, o2, m2 = s2(p2, o2, b)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    # rtol covers f32 reassociation noise between the accumulated and fused
    # reductions (larger at --xla_backend_optimization_level=0, see conftest)
    for a, c in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=3e-3, atol=1e-5)


@pytest.mark.slow
def test_dryrun_single_cell_subprocess():
    """The dry-run driver must succeed for a full-size cell on the 16x16
    mesh inside a fresh 512-device process (integration of deliverable e)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [
            sys.executable,
            "-m",
            "repro.launch.dryrun",
            "--arch",
            "olmoe-1b-7b",
            "--shape",
            "decode_32k",
            "--out",
            "/tmp/dryrun_test",
        ],
        capture_output=True,
        text=True,
        timeout=560,
        env=env,
        cwd=REPO,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr[-2000:]
    assert " ok " in proc.stdout


def test_skip_policy_matches_design():
    from repro.launch.dryrun import SKIPS

    for arch in ARCH_IDS:
        cfg = get_config(arch)
        if cfg.subquadratic:
            assert (arch, "long_500k") not in SKIPS
        else:
            assert (arch, "long_500k") in SKIPS
