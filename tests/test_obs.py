"""Tests for `repro.obs`: registry semantics, the REPRO_OBS gate, span
tracing, exporters, the drift monitor, and the unified telemetry surfaces
(health registry / knob cache / serving / train loop as obs views)."""

import json
import math

import numpy as np
import pytest

from repro import obs
from repro.obs import export as obs_export
from repro.obs import metrics as obs_metrics


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_counter_labels_and_total():
    c = obs_metrics.Registry().counter("x")
    c.inc()
    c.inc(2.0, op="gemm")
    c.inc(op="gemm")
    assert c.value() == 1.0
    assert c.value(op="gemm") == 3.0
    assert c.total() == 4.0


def test_gauge_last_write_wins():
    g = obs_metrics.Registry().gauge("g")
    g.set(1.0, ns="a")
    g.set(7.5, ns="a")
    assert g.value(ns="a") == 7.5
    assert g.value(ns="missing") is None


def test_histogram_summary_percentiles():
    h = obs_metrics.Histogram("h")
    for v in range(1, 101):
        h.observe(float(v))
    s = h.summary()
    assert s["count"] == 100
    assert s["sum"] == pytest.approx(5050.0)
    assert s["max"] == 100.0
    assert s["p50"] == pytest.approx(50.5)
    assert 95.0 <= s["p95"] <= 96.0
    assert 99.0 <= s["p99"] <= 100.0


def test_histogram_empty_summary_is_zeros():
    h = obs_metrics.Histogram("h")
    assert h.summary() == {
        "count": 0, "sum": 0.0, "mean": 0.0, "max": 0.0,
        "p50": 0.0, "p95": 0.0, "p99": 0.0,
    }


def test_registry_kind_clash_raises():
    reg = obs_metrics.Registry()
    reg.counter("m")
    with pytest.raises(TypeError):
        reg.gauge("m")


def test_snapshot_shape():
    obs.set_enabled(True)
    obs.inc("c", op="a")
    obs.set_gauge("g", 3.0)
    obs.observe("h", 1.0)
    snap = obs.snapshot()
    assert set(snap) == {"counters", "gauges", "histograms"}
    assert snap["counters"]["c"] == [{"labels": {"op": "a"}, "value": 1.0}]
    assert snap["gauges"]["g"][0]["value"] == 3.0
    assert snap["histograms"]["h"][0]["count"] == 1


# ---------------------------------------------------------------------------
# the REPRO_OBS gate
# ---------------------------------------------------------------------------


def test_env_gate_parsing(monkeypatch):
    obs.set_enabled(None)
    for off in ("0", "false", "OFF", "no"):
        monkeypatch.setenv("REPRO_OBS", off)
        assert not obs_metrics.enabled()
    for on in ("1", "true", "yes", "anything"):
        monkeypatch.setenv("REPRO_OBS", on)
        assert obs_metrics.enabled()
    monkeypatch.delenv("REPRO_OBS")
    assert obs_metrics.enabled()  # default on


def test_disabled_gate_drops_everything():
    obs.set_enabled(False)
    obs.inc("c")
    obs.set_gauge("g", 1.0)
    obs.observe("h", 1.0)
    with obs.span("ladder/run"):
        pass
    assert obs.registry().names() == []


def test_disabled_mode_sfc_matmul_records_zero_events():
    """REPRO_OBS=0 contract: a full knob-resolved kernel call records
    nothing — the counter-spy sees an empty registry, so the per-call
    cost of the instrumentation is one short-circuited branch."""
    import jax.numpy as jnp

    from repro.kernels.ops import sfc_matmul

    obs.set_enabled(False)
    a = jnp.asarray(np.random.default_rng(0).normal(size=(32, 32)), jnp.float32)
    jnp_out = np.asarray(a) @ np.asarray(a)
    out = sfc_matmul(a, a)
    np.testing.assert_allclose(np.asarray(out), jnp_out, rtol=1e-4, atol=1e-4)
    assert obs.registry().names() == []
    assert obs.snapshot() == {"counters": {}, "gauges": {}, "histograms": {}}


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------


def test_span_records_duration_histogram():
    obs.set_enabled(True)
    with obs.span("serving/prefill", request_id=7):
        pass
    h = obs.registry().histogram("span.serving/prefill_us")
    assert h.count() == 1
    assert h.summary()["max"] >= 0.0


def test_span_records_on_exception():
    obs.set_enabled(True)
    with pytest.raises(ValueError):
        with obs.span("train/step"):
            raise ValueError("boom")
    assert obs.registry().histogram("span.train/step_us").count() == 1


def test_span_taxonomy_is_documented():
    # every span name the instrumented call sites use must stay on the
    # documented taxonomy (README table + trace.SPAN_NAMES)
    assert len(obs.SPAN_NAMES) == 11
    assert len(set(obs.SPAN_NAMES)) == 11
    for name in obs.SPAN_NAMES:
        assert "/" in name


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------


def test_jsonl_roundtrip_and_require(tmp_path):
    obs.set_enabled(True)
    obs.inc("tune.cache.hit", op="gemm")
    obs.observe("serving.ttft_us", 1234.0)
    path = str(tmp_path / "t.jsonl")
    n = obs.to_jsonl(path)
    assert n == 2
    rows = obs.read_jsonl(path)
    by_name = {r["series"]: r for r in rows}
    assert by_name["tune.cache.hit"]["type"] == "counter"
    assert by_name["tune.cache.hit"]["value"] == 1.0
    assert by_name["tune.cache.hit"]["labels"] == {"op": "gemm"}
    hist = by_name["serving.ttft_us"]
    assert hist["type"] == "histogram"
    assert hist["count"] == 1 and hist["p95"] == pytest.approx(1234.0)
    assert obs.missing_series(path, ["serving.ttft_us"]) == []
    assert obs.missing_series(path, ["nope"]) == ["nope"]


def test_export_cli_gates_required_series(tmp_path, capsys):
    obs.set_enabled(True)
    obs.inc("ladder.served", namespace="gemm", rung="sfc_pallas")
    path = str(tmp_path / "t.jsonl")
    obs.to_jsonl(path)
    assert obs_export.main(["--check", path, "--require", "ladder.served"]) == 0
    assert obs_export.main(["--check", path, "--require", "absent.series"]) == 1
    assert "absent.series" in capsys.readouterr().err


def test_prometheus_text_format():
    obs.set_enabled(True)
    obs.inc("tune.cache.hit", op="gemm")
    obs.observe("span.ladder/run_us", 5.0)
    text = obs.to_prometheus()
    assert '# TYPE tune_cache_hit counter' in text
    assert 'tune_cache_hit{op="gemm"} 1.0' in text
    # histogram -> summary with quantile labels + _sum/_count
    assert 'span_ladder_run_us{quantile="0.95"} 5.0' in text
    assert "span_ladder_run_us_count 1" in text


# ---------------------------------------------------------------------------
# drift monitor
# ---------------------------------------------------------------------------


def test_drift_monitor_flags_and_recovers():
    mon = obs.DriftMonitor(threshold=0.5, window=16, min_samples=3)
    for _ in range(3):
        mon.observe("gemm", predicted_s=1.0, measured_s=1.05)
    assert mon.flagged() == ()
    with pytest.warns(RuntimeWarning, match="perf drift"):
        for _ in range(6):
            mon.observe("gemm", predicted_s=10.0, measured_s=1.0)
    assert mon.flagged() == ("gemm",)
    assert mon.median_error("gemm") > 0.5
    # enough healthy samples push the rolling median back under: flag lifts
    for _ in range(12):
        mon.observe("gemm", predicted_s=1.0, measured_s=1.0)
    assert mon.flagged() == ()


def test_drift_monitor_ignores_garbage_samples():
    mon = obs.DriftMonitor(min_samples=1)
    assert mon.observe("g", predicted_s=float("nan"), measured_s=1.0) is None
    assert mon.observe("g", predicted_s=1.0, measured_s=0.0) is None
    assert mon.observe("g", predicted_s=None, measured_s=1.0) is None
    assert mon.report() == {}


def test_miscalibrated_constant_flags_namespace_and_invalidates(tmp_path):
    """Acceptance: inject a deliberately mis-calibrated platform constant,
    tune through it, and the drift monitor flags the namespace as stale;
    invalidate_calibration() then purges the persisted constants."""
    import dataclasses as _dc

    from repro.tune import tune_gemm
    from repro.tune.cache import KnobCache
    from repro.tune.calibrate import PlatformConstants
    from repro.tune.tuner import _backend_name, _measure_simulated

    obs.set_enabled(True)
    backend = _backend_name()
    cache = KnobCache(path=str(tmp_path / "knobs.json"))
    # 300x throughput derate: predictions come out ~300x the simulator
    # measurement, an unmissable drift signal
    bad = PlatformConstants(
        device_kind=cache.device, backend=backend, time_scale=300.0,
        launch_overhead_s=0.0, flush_overhead_s=0.0, vmem_penalty=0.0,
        n_samples=8, median_abs_rel_err=0.01,
    )
    cache.put_platform(backend, bad.as_dict())

    mon = obs.get_monitor()
    with pytest.warns(RuntimeWarning, match="perf drift"):
        for shape in ((256, 256, 256), (512, 256, 128), (128, 512, 512)):
            tune_gemm(*shape, np.float32, cache=cache,
                      measure_fn=_measure_simulated)
    assert "gemm" in mon.flagged()
    assert (
        obs.registry().counter("drift.flagged").value(namespace="gemm") == 1.0
    )

    assert cache.get_platform(backend) is not None
    assert mon.invalidate_calibration(cache, backend=backend)
    assert cache.get_platform(backend) is None  # constants marked stale
    assert mon.flagged() == ()  # windows dropped: fresh verdict required


def test_well_calibrated_constant_does_not_flag(tmp_path):
    from repro.tune import tune_gemm
    from repro.tune.cache import KnobCache
    from repro.tune.tuner import _measure_simulated

    obs.set_enabled(True)
    cache = KnobCache(path=str(tmp_path / "knobs.json"))
    # no persisted constants: prediction and simulator measurement share
    # the datasheet model, so drift error is ~0
    for shape in ((256, 256, 256), (512, 256, 128), (128, 512, 512)):
        tune_gemm(*shape, np.float32, cache=cache,
                  measure_fn=_measure_simulated)
    mon = obs.get_monitor()
    assert mon.flagged() == ()
    med = mon.median_error("gemm")
    assert med is not None and med < 0.5


# ---------------------------------------------------------------------------
# unified surfaces: health registry / knob cache / serving / train loop
# ---------------------------------------------------------------------------


def test_degradation_report_is_view_over_obs_store():
    from repro.robust import get_registry

    obs.set_enabled(True)
    reg = get_registry()
    reg.record_served("gemm", "sfc_pallas", degraded=False)
    reg.record_served("gemm", "xla", degraded=True)
    reg.record_sdc("gemm", healed=True)
    rep = reg.degradation_report()
    assert rep["total_calls"] == 2
    assert rep["fallback_calls"] == 1
    assert rep["served"] == {"gemm": {"sfc_pallas": 1, "xla": 1}}
    assert rep["sdc"] == {"gemm": {"detected": 0, "healed": 1}}
    # the same events are mirrored into the gated process registry
    c = obs.registry().counter("ladder.served")
    assert c.value(namespace="gemm", rung="sfc_pallas") == 1.0
    assert c.value(namespace="gemm", rung="xla") == 1.0
    assert obs.registry().counter("ladder.fallback").total() == 1.0


def test_degradation_report_survives_disabled_obs():
    """The ledger is a private always-on store: turning telemetry export
    off must not blind degradation_report()."""
    from repro.robust import get_registry

    obs.set_enabled(False)
    reg = get_registry()
    reg.record_served("gemm", "xla", degraded=True)
    rep = reg.degradation_report()
    assert rep["total_calls"] == 1
    assert rep["served"] == {"gemm": {"xla": 1}}
    assert obs.registry().names() == []  # but nothing leaked to the export


def test_knob_cache_corrupt_counter_fires_every_occurrence(tmp_path):
    """Satellite bugfix: the log line is warn-once per path, but the
    counter must record EVERY corruption so fleets can alert on
    recurrence."""
    from repro.tune.cache import KnobCache, _WARNED_CORRUPT

    obs.set_enabled(True)
    path = str(tmp_path / "knobs.json")
    counter = obs.registry().counter("tune.cache.corrupt")

    with open(path, "w") as f:
        f.write("{not json")
    with pytest.warns(RuntimeWarning, match="corrupt"):
        assert KnobCache(path=path).get(64, 64, 64, np.float32, "cpu") is None
    assert counter.value(path=path) == 1.0
    assert path in _WARNED_CORRUPT

    # corrupt the rebuilt file again: warning stays deduplicated, the
    # counter keeps counting
    with open(path, "w") as f:
        f.write("{still not json")
    import warnings as _warnings

    with _warnings.catch_warnings():
        _warnings.simplefilter("error")  # a second warning would raise
        assert KnobCache(path=path).get(64, 64, 64, np.float32, "cpu") is None
    assert counter.value(path=path) == 2.0


def test_knob_cache_hit_miss_counters(tmp_path):
    from repro.tune.cache import KnobCache, Knobs

    obs.set_enabled(True)
    cache = KnobCache(path=str(tmp_path / "k.json"))
    assert cache.get(64, 64, 64, np.float32, "cpu") is None
    cache.put(64, 64, 64, np.float32, "cpu",
              Knobs(bm=32, bn=32, k_layers=1, k_block_factor=1))
    assert cache.get(64, 64, 64, np.float32, "cpu") is not None
    c = obs.registry()
    assert c.counter("tune.cache.miss").total() == 1.0
    assert c.counter("tune.cache.hit").total() == 1.0


def test_latency_report_percentiles_consistent_with_obs_store():
    from repro.serving.engine import Request, ServingEngine

    obs.set_enabled(True)
    rng = np.random.default_rng(7)
    reqs = []
    for i in range(40):
        ttft = float(rng.uniform(0.010, 0.200))
        n_tok = 8
        r = Request(uid=i, prompt=np.zeros(4, np.int32), max_new_tokens=n_tok)
        r.status = "completed"
        r.submitted_at = 100.0
        r.first_token_at = 100.0 + ttft
        r.done_at = r.first_token_at + 0.005 * (n_tok - 1)
        r.output = list(range(n_tok))
        reqs.append(r)
        ServingEngine._record_retired(r)

    rep = ServingEngine.latency_report(reqs)
    store = obs.registry().histogram("serving.ttft_us").summary()
    assert store["count"] == 40
    # report seconds vs store microseconds: same samples, same math
    assert rep["ttft_p50_s"] * 1e6 == pytest.approx(store["p50"], rel=1e-9)
    assert rep["ttft_p95_s"] * 1e6 == pytest.approx(store["p95"], rel=1e-9)
    assert rep["ttft_p99_s"] * 1e6 == pytest.approx(store["p99"], rel=1e-9)
    assert rep["ttft_mean_s"] * 1e6 == pytest.approx(store["mean"], rel=1e-9)
    tok = obs.registry().histogram("serving.token_us").summary()
    assert rep["token_p95_s"] * 1e6 == pytest.approx(tok["p95"], rel=1e-9)
    assert obs.registry().counter("serving.completed").total() == 40.0
    assert obs.registry().counter("serving.tokens").total() == 40.0 * 8


def test_structured_log_counts_and_forwards():
    obs.set_enabled(True)
    lines = []
    log = obs.as_structured(lines.append)
    log.event("ft.rollback", "[ft] oops: rolled back 5 -> 3", step=5)
    log("plain line")
    assert lines == ["[ft] oops: rolled back 5 -> 3", "plain line"]
    c = obs.registry().counter("log.events")
    assert c.value(kind="ft.rollback") == 1.0
    assert c.value(kind="info") == 1.0
    # idempotent coercion
    assert obs.as_structured(log) is log


def test_train_loop_on_metrics_and_structured_logger(tmp_path):
    from repro.train.checkpoint import CheckpointManager
    from repro.train.fault_tolerance import CorruptionPolicy, TrainLoop

    obs.set_enabled(True)

    def train_step(params, opt_state, batch, lr_scale=1.0):
        # batch_fn sees the loop's 0-based step; metrics report 1-based,
        # so batch step 1 == reported step 2
        loss = float("inf") if batch["step"] == 1 else 1.0 / (1 + batch["step"])
        return params, opt_state, {"loss": loss}

    def batch_fn(step):
        return {"step": step}

    seen = []
    logs = []
    loop = TrainLoop(
        train_step=train_step,
        batch_fn=batch_fn,
        ckpt=CheckpointManager(str(tmp_path / "ckpt"), interval=100),
        corruption_policy=CorruptionPolicy(skip_steps=2, rollback_on_sdc=False),
        on_metrics=seen.append,
    )
    loop.run({}, {}, num_steps=5, resume=False, log_every=2, logger=logs.append)

    assert len(seen) == 5
    assert set(seen[0]) == {
        "step", "loss", "dt_s", "nonfinite_streak", "sdc_delta", "lr_scale",
    }
    assert [m["step"] for m in seen] == [1, 2, 3, 4, 5]
    assert math.isinf(seen[1]["loss"]) and seen[1]["nonfinite_streak"] == 1
    assert seen[2]["nonfinite_streak"] == 0  # finite loss resets
    # the human lines still reach the injected sink
    assert any("nonfinite loss at step 2" in l for l in logs)
    assert any("recovered" in l for l in logs)
    assert any(l.startswith("[train] step=") for l in logs)
    # and the loop's telemetry landed in the registry
    reg = obs.registry()
    assert reg.counter("train.steps").total() == 5.0
    assert reg.counter("train.nonfinite").total() == 1.0
    assert reg.counter("log.events").value(kind="ft.nonfinite") == 1.0
    assert reg.histogram("span.train/step_us").count() == 5
    assert reg.histogram("train.step_us").count() == 5


def test_e2e_export_contains_every_series_family(tmp_path):
    """Acceptance: one (dummy-stepped) train-loop run plus one serving
    batch plus tune-cache and ABFT activity produce a JSONL export with
    the tune-cache, ladder, ABFT, serving-lifecycle, and train-step
    series families."""
    import jax.numpy as jnp

    from repro.robust import abft, get_registry
    from repro.serving.engine import Request, ServingEngine
    from repro.train.checkpoint import CheckpointManager
    from repro.train.fault_tolerance import TrainLoop
    from repro.tune.cache import KnobCache, Knobs

    obs.set_enabled(True)

    # tune-cache activity
    cache = KnobCache(path=str(tmp_path / "k.json"))
    cache.get(64, 64, 64, np.float32, "cpu")  # miss
    cache.put(64, 64, 64, np.float32, "cpu",
              Knobs(bm=32, bn=32, k_layers=1, k_block_factor=1))
    cache.get(64, 64, 64, np.float32, "cpu")  # hit

    # ladder activity
    get_registry().record_served("gemm", "sfc_pallas", degraded=False)

    # ABFT verify (eager, checksums agree)
    out = jnp.ones((4, 4), jnp.float32)
    chk = jnp.asarray(4.0)
    abft.verify("gemm", out, chk, jnp.asarray(4.0), jnp.asarray(1.0),
                contract_dim=4, mode="detect")

    # serving lifecycle
    r = Request(uid=1, prompt=np.zeros(4, np.int32), max_new_tokens=4)
    r.status = "completed"
    r.submitted_at, r.first_token_at, r.done_at = 1.0, 1.1, 1.4
    r.output = [1, 2, 3, 4]
    ServingEngine._record_retired(r)

    # train loop
    loop = TrainLoop(
        train_step=lambda p, o, b: (p, o, {"loss": 0.5}),
        batch_fn=lambda step: {},
        ckpt=CheckpointManager(str(tmp_path / "ckpt"), interval=100),
    )
    loop.run({}, {}, num_steps=3, resume=False, logger=lambda _line: None)

    path = str(tmp_path / "telemetry.jsonl")
    obs.to_jsonl(path)
    assert obs.missing_series(path, [
        "tune.cache.miss", "tune.cache.hit",
        "ladder.served",
        "abft.checks",
        "serving.ttft_us", "serving.completed", "serving.tokens",
        "train.steps", "train.step_us", "span.train/step_us",
    ]) == []
    # every row is valid standalone JSON with the schema fields
    for line in open(path):
        row = json.loads(line)
        assert {"series", "type", "labels"} <= set(row)
