"""Shared pytest configuration.

Registers the ``slow`` marker workflow: tests marked ``@pytest.mark.slow``
(long subprocess integration runs, heavy per-arch compiles) are deselected
by default so the tier-1 command finishes in well under two minutes on CPU;
``--runslow`` opts back in (nightly / pre-release runs).
"""

import os

import pytest

# tier-1 is XLA-compile-bound (dozens of tiny jitted model graphs); backend
# optimization buys nothing at toy sizes, so trade compiled-code quality for
# compile latency.  Respect an explicit caller override.
if "--xla_backend_optimization_level" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " --xla_backend_optimization_level=0"
    ).strip()


@pytest.fixture(autouse=True)
def fresh_health_registry():
    """Reset the fallback-ladder health registry around every test.

    A quarantine leaking between tests would silently reroute later
    kernel launches onto the jnp fallback rungs — which introduce
    `dot_general`, breaking the zero-dot_general jaxpr gates — so
    isolation here is load-bearing, not hygiene."""
    from repro.robust import get_registry

    get_registry().reset()
    yield
    get_registry().reset()


@pytest.fixture(autouse=True)
def fresh_obs_registry():
    """Reset the process-wide obs metrics registry and drift monitor
    around every test (mirroring `fresh_health_registry`): counters
    accumulated by one test must not leak into another's assertions,
    and a drift flag raised by an injected mis-calibration must not
    outlive the test that injected it."""
    from repro import obs

    obs.reset_all()
    obs.set_enabled(None)
    yield
    obs.reset_all()
    obs.set_enabled(None)


def pytest_sessionfinish(session, exitstatus):
    """With REPRO_DEGRADATION_REPORT=<path> set, write the final health
    registry as JSON — the strict CI job uploads it as an artifact."""
    path = os.environ.get("REPRO_DEGRADATION_REPORT")
    if not path:
        return
    try:
        import json

        from repro.robust import degradation_report

        with open(path, "w") as f:
            json.dump(degradation_report(), f, indent=2, sort_keys=True)
    except Exception as exc:  # never fail the run over the artifact
        print(f"[conftest] degradation report not written: {exc}")


@pytest.fixture(autouse=True, scope="session")
def isolated_tune_cache(tmp_path_factory):
    """Point the SFC knob cache at a per-session temp file so test runs never
    read or pollute the developer's ~/.cache tuning results."""
    os.environ["REPRO_SFC_TUNE_CACHE"] = str(
        tmp_path_factory.mktemp("tune") / "knobs.json"
    )
    try:
        import repro.tune.tuner as tuner

        tuner._DEFAULT_CACHE = None
    except ImportError:
        pass
    yield


def pytest_addoption(parser):
    parser.addoption(
        "--runslow",
        action="store_true",
        default=False,
        help="also run tests marked slow (deselected by default)",
    )


def pytest_configure(config):
    # registered here as well as pyproject.toml so bare invocations
    # (no rootdir config) never warn on unknown markers
    config.addinivalue_line(
        "markers", "slow: long-running test, deselected unless --runslow"
    )


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip_slow = pytest.mark.skip(reason="slow: pass --runslow to run")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)
