"""Shared pytest configuration.

Registers the ``slow`` marker workflow: tests marked ``@pytest.mark.slow``
(long subprocess integration runs, heavy per-arch compiles) are deselected
by default so the tier-1 command finishes in well under two minutes on CPU;
``--runslow`` opts back in (nightly / pre-release runs).
"""

import os

import pytest

# tier-1 is XLA-compile-bound (dozens of tiny jitted model graphs); backend
# optimization buys nothing at toy sizes, so trade compiled-code quality for
# compile latency.  Respect an explicit caller override.
if "--xla_backend_optimization_level" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " --xla_backend_optimization_level=0"
    ).strip()


@pytest.fixture(autouse=True, scope="session")
def isolated_tune_cache(tmp_path_factory):
    """Point the SFC knob cache at a per-session temp file so test runs never
    read or pollute the developer's ~/.cache tuning results."""
    os.environ["REPRO_SFC_TUNE_CACHE"] = str(
        tmp_path_factory.mktemp("tune") / "knobs.json"
    )
    try:
        import repro.tune.tuner as tuner

        tuner._DEFAULT_CACHE = None
    except ImportError:
        pass
    yield


def pytest_addoption(parser):
    parser.addoption(
        "--runslow",
        action="store_true",
        default=False,
        help="also run tests marked slow (deselected by default)",
    )


def pytest_configure(config):
    # registered here as well as pyproject.toml so bare invocations
    # (no rootdir config) never warn on unknown markers
    config.addinivalue_line(
        "markers", "slow: long-running test, deselected unless --runslow"
    )


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip_slow = pytest.mark.skip(reason="slow: pass --runslow to run")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)
