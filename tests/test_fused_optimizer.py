"""Grad-and-update fusion: AdamW in the TN kernel flush.

Differential tests of the fused TN-update against the unfused composition
(TN GEMM -> `adamw_leaf_update`), the bf16 stochastic-rounding contract
(deterministic per seed, mean-unbiased over seeds), the fused train step
against the unfused one, and structural jaxpr checks: for routed weights
the fused step contains no standalone optimizer elementwise pass — the
update lives inside the Pallas kernels.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gemm_backend as gb
from repro.kernels.ops import (
    fused_update_glu_matmul,
    fused_update_matmul,
    sfc_grouped_matmul_tn_update,
    sfc_matmul_tn,
    sfc_matmul_tn_update,
)
from repro.kernels.sfc_gemm import stochastic_round_to, tile_random_bits
from repro.optim.adamw import (
    HYP_SALT,
    HYP_SEED,
    AdamWConfig,
    adamw_init,
    adamw_leaf_update,
    adamw_scalars,
    pack_adamw_hyper,
    seed_to_lane,
)
from repro.optim.fused import probe_routed
from repro.train.step import BackendConfig, make_train_step


def _rand(*shape, dtype=jnp.float32, seed=0, scale=1.0):
    rng = np.random.default_rng([seed, *[int(s) for s in shape]])
    return jnp.asarray(rng.normal(size=shape) * scale, dtype)


CFG = AdamWConfig()


def _state(k, n, seed=0):
    return (
        _rand(k, n, seed=seed + 1, scale=0.5),
        _rand(k, n, seed=seed + 2, scale=0.1),
        jnp.abs(_rand(k, n, seed=seed + 3, scale=0.01)),
    )


def _reference_update(dw, mst, mu, nu, step, scale):
    lr, b1c, b2c = adamw_scalars(CFG, step)
    rmu, rnu, rmst = adamw_leaf_update(
        dw, mu, nu, mst,
        lr=lr, b1=CFG.b1, b2=CFG.b2, eps=CFG.eps,
        weight_decay=CFG.weight_decay, b1c=b1c, b2c=b2c, scale=scale,
    )
    return rmst, rmu, rnu


# ---------------------------------------------------------------------------
# kernel-level differential: fused flush == unfused TN + elementwise AdamW
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(48, 32, 40), (130, 96, 72)])
def test_tn_update_matches_unfused_composition_f32(shape):
    m, k, n = shape
    a, dy = _rand(m, k), _rand(m, n, seed=1)
    mst, mu, nu = _state(k, n)
    step = jnp.asarray(7, jnp.int32)
    scale = jnp.float32(0.6)
    hyper = pack_adamw_hyper(CFG, step, scale)

    w_n, mst_n, mu_n, nu_n, sq = sfc_matmul_tn_update(
        a, dy, mst, mu, nu, hyper,
        param_dtype=jnp.float32, interpret=True,
    )
    # unfused composition: the TN kernel writes dW, AdamW reads it back
    dw = sfc_matmul_tn(a, dy, interpret=True, out_dtype=jnp.float32)
    rmst, rmu, rnu = _reference_update(dw, mst, mu, nu, step, scale)

    for got, want in ((mst_n, rmst), (mu_n, rmu), (nu_n, rnu), (w_n, rmst)):
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6
        )
    np.testing.assert_allclose(
        float(sq), float(jnp.sum(dw.astype(jnp.float32) ** 2)), rtol=1e-5
    )


def test_tn_update_dual_matches_unfused():
    m, k, n = 40, 24, 32
    a = _rand(m, k)
    dy, dy2 = _rand(m, n, seed=1), _rand(m, n, seed=2)
    mst, mu, nu = _state(k, n)
    mst2, mu2, nu2 = _state(k, n, seed=10)
    step = jnp.asarray(3, jnp.int32)
    hyper = pack_adamw_hyper(CFG, step, jnp.float32(1.0))

    set_v, set_g = sfc_matmul_tn_update(
        a, dy, mst, mu, nu, hyper, dy2, mst2, mu2, nu2,
        param_dtype=jnp.float32, interpret=True,
    )
    for (dyi, sti, got) in (
        (dy, (mst, mu, nu), set_v),
        (dy2, (mst2, mu2, nu2), set_g),
    ):
        dw = sfc_matmul_tn(a, dyi, interpret=True, out_dtype=jnp.float32)
        rmst, rmu, rnu = _reference_update(dw, *sti, step, 1.0)
        np.testing.assert_allclose(np.asarray(got[1]), np.asarray(rmst), rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(got[2]), np.asarray(rmu), rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(
            float(got[4]), float(jnp.sum(dw ** 2)), rtol=1e-5
        )


def test_grouped_tn_update_matches_per_expert():
    gs = (10, 0, 23)  # middle expert empty: g = 0 update must still apply
    k, n = 16, 24
    t = sum(gs)
    a, dy = _rand(t, k), _rand(t, n, seed=1)
    e = len(gs)
    mst = _rand(e, k, n, seed=4, scale=0.5)
    mu = _rand(e, k, n, seed=5, scale=0.1)
    nu = jnp.abs(_rand(e, k, n, seed=6, scale=0.01))
    step = jnp.asarray(2, jnp.int32)
    hyper = pack_adamw_hyper(CFG, step, jnp.float32(1.0))

    w_n, mst_n, mu_n, nu_n, sq = sfc_grouped_matmul_tn_update(
        a, dy, gs, mst, mu, nu, hyper,
        param_dtype=jnp.float32, interpret=True,
    )
    off, total_sq = 0, 0.0
    for ei, g in enumerate(gs):
        dw = (
            a[off : off + g].T @ dy[off : off + g]
            if g
            else jnp.zeros((k, n), jnp.float32)
        )
        rmst, rmu, rnu = _reference_update(
            dw, mst[ei], mu[ei], nu[ei], step, 1.0
        )
        np.testing.assert_allclose(
            np.asarray(mst_n[ei]), np.asarray(rmst), rtol=1e-5, atol=1e-6
        )
        np.testing.assert_allclose(
            np.asarray(mu_n[ei]), np.asarray(rmu), rtol=1e-5, atol=1e-6
        )
        total_sq += float(jnp.sum(dw ** 2))
        off += g
    np.testing.assert_allclose(float(sq), total_sq, rtol=1e-4)


# ---------------------------------------------------------------------------
# bf16 stochastic rounding: deterministic per seed, unbiased over seeds
# ---------------------------------------------------------------------------


def test_stochastic_round_unbiased_and_deterministic():
    x = jnp.linspace(-2.0, 2.0, 1024, dtype=jnp.float32).reshape(8, 128) + 1e-3
    acc = jnp.zeros_like(x)
    n_seeds = 64
    for s in range(n_seeds):
        bits = tile_random_bits(x.shape, jnp.int32(s), hw_rng=False)
        acc = acc + stochastic_round_to(x, bits, jnp.bfloat16).astype(jnp.float32)
    mean = acc / n_seeds
    # one bf16 ulp at |x|~2 is ~2^-7; the mean must sit well inside it
    np.testing.assert_allclose(np.asarray(mean), np.asarray(x), atol=4e-3)
    # fixed seed => bit-identical
    b0 = tile_random_bits(x.shape, jnp.int32(5), hw_rng=False)
    b1 = tile_random_bits(x.shape, jnp.int32(5), hw_rng=False)
    assert bool(jnp.all(b0 == b1))
    r0 = stochastic_round_to(x, b0, jnp.bfloat16)
    assert bool(jnp.all(r0 == stochastic_round_to(x, b1, jnp.bfloat16)))


def test_kernel_sr_deterministic_and_unbiased():
    m, k, n = 32, 16, 24
    a, dy = _rand(m, k), _rand(m, n, seed=1)
    mst, mu, nu = _state(k, n)

    def run(step):
        hyper = pack_adamw_hyper(
            CFG, jnp.asarray(step, jnp.int32), jnp.float32(1.0)
        )
        return sfc_matmul_tn_update(
            a, dy, mst, mu, nu, hyper,
            param_dtype=jnp.bfloat16, stochastic_round=True, interpret=True,
        )

    w_a = run(4)
    w_b = run(4)
    assert bool(jnp.all(w_a[0] == w_b[0])), "fixed (step, tile) seed must be deterministic"
    assert w_a[0].dtype == jnp.bfloat16
    # rounded value within one bf16 ulp of the f32 master
    err = jnp.abs(w_a[0].astype(jnp.float32) - w_a[1])
    ulp = jnp.maximum(jnp.abs(w_a[1]) * 2.0 ** -7, 2.0 ** -126)
    assert bool(jnp.all(err <= ulp))
    # mean over many steps (different seeds, same update inputs except the
    # tiny lr drift across steps is avoided by fixing the packed scalars):
    hyper4 = pack_adamw_hyper(CFG, jnp.asarray(4, jnp.int32), jnp.float32(1.0))
    base = sfc_matmul_tn_update(
        a, dy, mst, mu, nu, hyper4, param_dtype=jnp.float32, interpret=True
    )[1]
    acc = jnp.zeros_like(base)
    n_seeds = 32
    for s in range(n_seeds):
        hyper_s = hyper4.at[HYP_SEED].set(
            seed_to_lane(jnp.asarray(1000 + s, jnp.int32))
        )
        w = sfc_matmul_tn_update(
            a, dy, mst, mu, nu, hyper_s,
            param_dtype=jnp.bfloat16, stochastic_round=True, interpret=True,
        )[0]
        acc = acc + w.astype(jnp.float32)
    resid = jnp.abs(acc / n_seeds - base)
    # SR noise shrinks as 1/sqrt(n): the mean must land far inside one ulp
    assert float(jnp.mean(resid)) < float(jnp.mean(jnp.abs(base))) * 2.0 ** -8


def test_kernel_sr_salt_decorrelates_leaves():
    """Two routed weights with identical tile grids must not share a dither
    stream: the per-leaf salt lane changes the rounded bits."""
    m, k, n = 32, 16, 24
    a, dy = _rand(m, k), _rand(m, n, seed=1)
    mst, mu, nu = _state(k, n)
    hyper = pack_adamw_hyper(CFG, jnp.asarray(4, jnp.int32), jnp.float32(1.0))

    def run(salt):
        h = hyper.at[HYP_SALT].set(seed_to_lane(jnp.asarray(salt, jnp.int32)))
        return sfc_matmul_tn_update(
            a, dy, mst, mu, nu, h,
            param_dtype=jnp.bfloat16, stochastic_round=True, interpret=True,
        )[0]

    w_a, w_b = run(1 << 16), run(2 << 16)
    assert bool(jnp.any(w_a != w_b)), "distinct salts must give distinct bits"
    assert bool(jnp.all(run(1 << 16) == w_a)), "same salt stays deterministic"


# ---------------------------------------------------------------------------
# custom-VJP level: fused backward == unfused oracle composition
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("activation", [None, "gelu"])
def test_fused_update_core_matches_oracle(activation):
    m, k, n = 24, 16, 40
    x = _rand(2, m, k)
    w = _rand(k, n, scale=0.1)
    mst, mu, nu = jnp.array(w), jnp.zeros((k, n)), jnp.zeros((k, n))
    hyper = pack_adamw_hyper(CFG, jnp.asarray(1, jnp.int32), jnp.float32(1.0))
    tok = jnp.zeros(())

    def loss(x, w, mst, mu, nu, hyper, tok, backend):
        y = fused_update_matmul(
            x, w, mst, mu, nu, hyper, tok,
            backend=backend, activation=activation, stochastic_round=False,
        )
        return jnp.sum(y ** 2)

    grad = jax.value_and_grad(loss, argnums=(0, 1, 2, 3, 4, 6))
    vp, cp = grad(x, w, mst, mu, nu, hyper, tok, "sfc_pallas")
    vx, cx = grad(x, w, mst, mu, nu, hyper, tok, "xla")
    np.testing.assert_allclose(float(vp), float(vx), rtol=1e-6)
    for got, want in zip(jax.tree.leaves(cp), jax.tree.leaves(cx)):
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5
        )
    # the update really applied: W_new != W and sq > 0
    assert bool(jnp.any(cp[1] != w)) and float(cp[5]) > 0


def test_fused_update_glu_core_matches_oracle():
    m, k, n = 24, 16, 32
    x = _rand(m, k)
    wg, wv = _rand(k, n, seed=1, scale=0.1), _rand(k, n, seed=2, scale=0.1)
    og = (jnp.array(wg), jnp.zeros((k, n)), jnp.zeros((k, n)))
    ov = (jnp.array(wv), jnp.zeros((k, n)), jnp.zeros((k, n)))
    hyper = pack_adamw_hyper(CFG, jnp.asarray(1, jnp.int32), jnp.float32(1.0))
    toks = (jnp.zeros(()), jnp.zeros(()))

    def loss(x, wg, wv, og, ov, backend):
        y = fused_update_glu_matmul(
            x, wg, wv, og, ov, hyper, toks,
            backend=backend, stochastic_round=False,
        )
        return jnp.sum(y ** 2)

    grad = jax.value_and_grad(loss, argnums=(0, 1, 2, 3, 4))
    vp, cp = grad(x, wg, wv, og, ov, "sfc_pallas")
    vx, cx = grad(x, wg, wv, og, ov, "xla")
    np.testing.assert_allclose(float(vp), float(vx), rtol=1e-6)
    for got, want in zip(jax.tree.leaves(cp), jax.tree.leaves(cx)):
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4
        )


# ---------------------------------------------------------------------------
# train-step level: a minimal two-projection model exercises probe + wrap +
# cotangent plumbing without the cost of a full transformer
# ---------------------------------------------------------------------------


class _MiniModel:
    """Two dense projections + an elementwise head; params include a norm
    scale (elementwise-consumed -> must be auto-excluded by the probe)."""

    def init(self, key):
        k1, k2 = jax.random.split(key)
        return {
            "w1": (jax.random.normal(k1, (16, 32)) * 0.1).astype(jnp.float32),
            "w2": (jax.random.normal(k2, (32, 8)) * 0.1).astype(jnp.float32),
            "scale": jnp.ones((16,), jnp.float32),
        }

    def loss(self, params, batch, *, remat="none"):
        x = batch["x"] * params["scale"]
        h = gb.matmul(x, params["w1"], activation="gelu")
        y = gb.matmul(h, params["w2"])
        return jnp.mean((y - batch["y"]) ** 2)


@pytest.fixture()
def mini():
    model = _MiniModel()
    params = model.init(jax.random.PRNGKey(0))
    batch = {"x": _rand(6, 16, seed=3), "y": _rand(6, 8, seed=4)}
    return model, params, batch


def test_probe_routes_projections_only(mini):
    model, params, batch = mini

    def probe_loss(p, b):
        with gb.gemm_backend("xla"):
            return model.loss(p, b)

    routed = probe_routed(probe_loss, params, batch)
    assert set(routed) == {"w1", "w2"}
    assert not routed["w1"].stacked


def test_fused_step_matches_unfused_f32(mini):
    model, params, batch = mini
    cfg = AdamWConfig(lr=1e-2, total_steps=10, warmup_steps=1, clip_norm=1e9)

    unfused = make_train_step(model, cfg, remat="none", backend=BackendConfig(gemm_backend="xla"))
    st_u = adamw_init(params)
    p_u, s_u, m_u = unfused(params, st_u, batch)

    for backend in ("sfc_pallas", "xla"):
        fused = make_train_step(
            model, cfg, remat="none", backend=BackendConfig(gemm_backend=backend, fused_optimizer=True, stochastic_round=False),
        )
        st_f = adamw_init(params, with_gnorm=True)
        p_f, s_f, m_f = fused(params, st_f, batch)
        np.testing.assert_allclose(float(m_f["loss"]), float(m_u["loss"]), rtol=1e-6)
        np.testing.assert_allclose(
            float(m_f["grad_norm"]), float(m_u["grad_norm"]), rtol=1e-5
        )
        for got, want in zip(jax.tree.leaves(p_f), jax.tree.leaves(p_u)):
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6,
                err_msg=f"backend={backend}",
            )
        for slot in ("mu", "nu", "master"):
            for got, want in zip(
                jax.tree.leaves(s_f[slot]), jax.tree.leaves(s_u[slot])
            ):
                np.testing.assert_allclose(
                    np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6
                )


def test_fused_step_exact_clip_matches_unfused_f32(mini):
    """Exact clipping (the two-phase flush): with a clip_norm that actually
    bites, the fused step must advance every leaf identically to the
    unfused step — which clips by the *current* step's global norm — at
    f32, on both the kernel and oracle backends.  No with_gnorm state is
    needed any more."""
    model, params, batch = mini
    # pick a clip well below the actual first-step norm so the scale != 1
    probe_cfg = AdamWConfig(lr=1e-2, total_steps=10, warmup_steps=1)
    unfused_probe = make_train_step(model, probe_cfg, remat="none",
                                    backend=BackendConfig(gemm_backend="xla"))
    _, _, m_probe = unfused_probe(params, adamw_init(params), batch)
    clip = 0.5 * float(m_probe["grad_norm"])
    assert clip > 0
    cfg = AdamWConfig(lr=1e-2, total_steps=10, warmup_steps=1,
                      clip_norm=clip)

    unfused = make_train_step(model, cfg, remat="none", backend=BackendConfig(gemm_backend="xla"))
    p_u, s_u, m_u = unfused(params, adamw_init(params), batch)
    assert float(m_u["grad_norm"]) > clip, "clip must actually engage"

    for backend in ("sfc_pallas", "xla"):
        fused = make_train_step(
            model, cfg, remat="none", backend=BackendConfig(gemm_backend=backend, fused_optimizer=True, stochastic_round=False),
        )
        p_f, s_f, m_f = fused(params, adamw_init(params), batch)
        np.testing.assert_allclose(
            float(m_f["grad_norm"]), float(m_u["grad_norm"]), rtol=1e-5
        )
        for got, want in zip(jax.tree.leaves(p_f), jax.tree.leaves(p_u)):
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6,
                err_msg=f"backend={backend}",
            )
        for slot in ("mu", "nu", "master"):
            for got, want in zip(
                jax.tree.leaves(s_f[slot]), jax.tree.leaves(s_u[slot])
            ):
                np.testing.assert_allclose(
                    np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6,
                    err_msg=f"backend={backend} slot={slot}",
                )
        # two consecutive steps stay exact (the second step's clip scale
        # uses the second step's own norm, not a carried one)
        p_u2, s_u2, m_u2 = unfused(p_u, s_u, batch)
        p_f2, s_f2, m_f2 = fused(p_f, s_f, batch)
        np.testing.assert_allclose(
            float(m_f2["grad_norm"]), float(m_u2["grad_norm"]), rtol=1e-5
        )
        for got, want in zip(jax.tree.leaves(p_f2), jax.tree.leaves(p_u2)):
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5,
                err_msg=f"backend={backend} step2",
            )


def test_fused_step_legacy_gnorm_state_still_accepted(mini):
    """States initialized with adamw_init(with_gnorm=True) keep working:
    the slot is carried through (now informational — it holds the current
    step's exact norm) and the pytree structure stays stable across
    steps."""
    model, params, batch = mini
    cfg = AdamWConfig(lr=1e-2, total_steps=10, warmup_steps=1, clip_norm=0.5)
    fused = make_train_step(
        model, cfg, remat="none", backend=BackendConfig(gemm_backend="sfc_pallas", fused_optimizer=True, stochastic_round=False),
    )
    st = adamw_init(params, with_gnorm=True)
    p1, s1, m1 = fused(params, st, batch)
    assert float(s1["gnorm"]) == float(m1["grad_norm"]) > 0
    p2, s2, m2 = fused(p1, s1, batch)
    assert jax.tree_util.tree_structure(s2) == jax.tree_util.tree_structure(s1)


def _count_elementwise_at_shape(jaxpr, shape, counts=None):
    """Count non-pallas elementwise eqns whose every in/outvar has `shape`
    — the signature of a standalone optimizer pass over a routed weight."""
    elementwise = {
        "add", "sub", "mul", "div", "sqrt", "rsqrt", "integer_pow",
        "max", "min",
    }
    if counts is None:
        counts = {"n": 0}
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            continue
        shapes = [tuple(v.aval.shape) for v in (*eqn.invars, *eqn.outvars)
                  if hasattr(v, "aval")]
        if (
            eqn.primitive.name in elementwise
            and shapes
            and all(s == shape for s in shapes)
        ):
            counts["n"] += 1
        for val in eqn.params.values():
            _walk_param(val, shape, counts)
    return counts


def _walk_param(val, shape, counts):
    if isinstance(val, jax.core.ClosedJaxpr):
        _count_elementwise_at_shape(val.jaxpr, shape, counts)
    elif isinstance(val, jax.core.Jaxpr):
        _count_elementwise_at_shape(val, shape, counts)
    elif isinstance(val, (tuple, list)):
        for v in val:
            _walk_param(v, shape, counts)


def test_fused_step_jaxpr_has_no_optimizer_pass_for_routed_weights(mini):
    """The acceptance-criterion structural check: the fused train step's
    jaxpr contains zero standalone elementwise optimizer ops at a routed
    weight's shape (they live inside the TN-update pallas_call), while the
    unfused step contains the full AdamW chain."""
    model, params, batch = mini
    cfg = AdamWConfig(lr=1e-2, total_steps=10, warmup_steps=1)
    w_shape = tuple(params["w1"].shape)

    fused = make_train_step(
        model, cfg, remat="none", backend=BackendConfig(gemm_backend="sfc_pallas", fused_optimizer=True, stochastic_round=False),
    )
    unfused = make_train_step(model, cfg, remat="none", backend=BackendConfig(gemm_backend="sfc_pallas"))

    st_f = adamw_init(params, with_gnorm=True)
    st_u = adamw_init(params)
    jx_f = jax.make_jaxpr(fused)(params, st_f, batch)
    jx_u = jax.make_jaxpr(unfused)(params, st_u, batch)

    n_fused = _count_elementwise_at_shape(jx_f.jaxpr, w_shape)["n"]
    n_unfused = _count_elementwise_at_shape(jx_u.jaxpr, w_shape)["n"]
    assert n_unfused > 0, "unfused step should run elementwise AdamW"
    assert n_fused == 0, (
        f"fused step still runs {n_fused} standalone elementwise ops at "
        f"routed weight shape {w_shape}"
    )


def test_fused_step_rejects_microbatching(mini):
    model, _, _ = mini
    with pytest.raises(ValueError, match="microbatches"):
        make_train_step(
            model, AdamWConfig(), backend=BackendConfig(fused_optimizer=True), microbatches=2
        )


# ---------------------------------------------------------------------------
# warmup fills the backward-dual + update namespaces (table-driven)
# ---------------------------------------------------------------------------


def test_warmup_tunes_dual_and_update_namespaces(monkeypatch):
    from repro.configs import get_config
    from repro.core.perf_model import backward_gemm_shapes
    from repro.models.registry import build_model
    from repro.serving.engine import ServingEngine

    cfg = get_config("qwen3_4b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, max_batch=2, max_seq=16,
                           gemm_backend="sfc_pallas")

    calls = []

    def fake_tune(m, n, k, dtype, op="gemm", **kw):
        calls.append((op, m, n, k))

    import repro.tune

    monkeypatch.setattr(repro.tune, "tune_gemm", fake_tune)
    monkeypatch.setattr(repro.tune, "calibrate", lambda *a, **k: None)
    monkeypatch.setattr(
        ServingEngine, "warmup", _warmup_tune_only(ServingEngine.warmup)
    )
    engine.warmup(prompt_len=8, tune_update=True)

    ops_seen = {c[0] for c in calls}
    fwd = engine.projection_gemm_shapes(8)
    assert any(op == "glu" for op, *_ in fwd), "config should have a gated MLP"
    assert {"nt", "tn", "nt_dual", "tn_dual", "tn_update",
            "tn_update_dual"} <= ops_seen
    # the dual namespaces are exactly the GLU projections' backward buckets
    for op, m, n, k in fwd:
        bwd = backward_gemm_shapes(m, n, k)
        suffix = "_dual" if op == "glu" else ""
        assert ("nt" + suffix, *bwd["nt"]) in calls
        assert ("tn" + suffix, *bwd["tn"]) in calls
        assert ("tn_update" + suffix, *bwd["tn"]) in calls


def _warmup_tune_only(orig):
    """Run warmup's tuning loop but skip the compile (prefill/decode) tail."""

    def warmup(self, prompt_len=32, **kw):
        try:
            orig(self, prompt_len, **kw)
        except Exception:
            # the reduced config may not compile a decode step in this
            # harness; the tuning loop runs before compilation, which is
            # all this test asserts
            pass

    return warmup


def test_tn_update_tuner_namespace_roundtrip(tmp_path, monkeypatch):
    """`tune_gemm(op="tn_update")` measures the real update op and persists
    under the op-suffixed cache key the resolver consults."""
    import repro.tune.tuner as tuner
    from repro.tune import KnobCache, tune_gemm

    monkeypatch.setenv("REPRO_SFC_TUNE_CACHE", str(tmp_path / "knobs.json"))
    tuner._DEFAULT_CACHE = None
    try:
        kn = tune_gemm(32, 24, 16, np.float32, op="tn_update",
                       max_candidates=2)
        cache = KnobCache(str(tmp_path / "knobs.json"))
        key = cache.key(32, 24, 16, np.float32, "cpu", "tn_update")
        assert key.endswith("|tn_update")
        hit = cache.get(32, 24, 16, np.float32, "cpu", "tn_update")
        assert hit is not None and hit.bm == kn.bm
        # and the plain tn namespace is untouched
        assert cache.get(32, 24, 16, np.float32, "cpu", "tn") is None
    finally:
        tuner._DEFAULT_CACHE = None


# ---------------------------------------------------------------------------
# MoE fused-optimizer routing: expert stacks through the grouped TN flush
# ---------------------------------------------------------------------------


def _moe_cfg():
    from repro.configs.base import ArchConfig

    return ArchConfig(
        name="tiny_moe_fused", family="moe", n_layers=2, d_model=32,
        n_heads=4, kv_heads=2, d_ff=48, vocab=64, head_dim=8,
        n_experts=4, moe_top_k=2, param_dtype="float32",
        q_chunk=16, k_chunk=16,
    )


def _moe_fixture():
    from repro.models.registry import build_model

    cfg = _moe_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)), jnp.int32),
    }
    return model, params, batch


def test_probe_routes_moe_expert_stacks():
    """The probe now accepts 3-D grouped consumption: scan-stacked expert
    stacks (L, E, K, N) route as grouped/grouped_glu, alongside the 2-D
    projections."""
    model, params, batch = _moe_fixture()

    def probe_loss(p, b):
        with gb.gemm_backend("xla"):
            return model.loss(p, b, remat="none")

    routed = probe_routed(probe_loss, params, batch)
    assert routed["layers/moe/w_in"].op == "grouped_glu"
    assert routed["layers/moe/w_gate"].op == "grouped_glu"
    assert routed["layers/moe/w_out"].op == "grouped"
    for p in ("layers/moe/w_in", "layers/moe/w_gate", "layers/moe/w_out"):
        assert routed[p].stacked  # (L, E, K, N) consumed as (E, K, N)
    assert routed["layers/attn/wq"].op == "matmul"


def test_moe_fused_step_matches_unfused_f32():
    """Acceptance (ROADMAP "MoE fused-optimizer routing"): the fused step
    with expert stacks routed through `sfc_grouped_matmul_tn_update`
    advances every leaf — expert weights included — identically to the
    unfused composition at f32, on both the kernel and oracle backends."""
    model, params, batch = _moe_fixture()
    cfg = AdamWConfig(lr=1e-2, total_steps=10, warmup_steps=1, clip_norm=1e9)

    unfused = make_train_step(model, cfg, remat="none", backend=BackendConfig(gemm_backend="xla"))
    p_u, s_u, m_u = unfused(params, adamw_init(params), batch)

    for backend in ("sfc_pallas", "xla"):
        fused = make_train_step(
            model, cfg, remat="none", backend=BackendConfig(gemm_backend=backend, fused_optimizer=True, stochastic_round=False),
        )
        p_f, s_f, m_f = fused(params, adamw_init(params, with_gnorm=True), batch)
        np.testing.assert_allclose(
            float(m_f["loss"]), float(m_u["loss"]), rtol=1e-6
        )
        np.testing.assert_allclose(
            float(m_f["grad_norm"]), float(m_u["grad_norm"]), rtol=1e-5
        )
        for got, want in zip(jax.tree.leaves(p_f), jax.tree.leaves(p_u)):
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5,
                err_msg=f"backend={backend}",
            )
        for slot in ("mu", "nu", "master"):
            for got, want in zip(
                jax.tree.leaves(s_f[slot]), jax.tree.leaves(s_u[slot])
            ):
                np.testing.assert_allclose(
                    np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5,
                    err_msg=f"backend={backend} slot={slot}",
                )


def test_moe_fused_step_jaxpr_no_expert_optimizer_pass():
    """Structural: the fused step's jaxpr contains zero standalone
    elementwise optimizer ops at the scan-stacked expert-weight shape —
    the per-expert AdamW lives inside the grouped TN-update pallas_call —
    while the unfused step carries the full chain there."""
    model, params, batch = _moe_fixture()
    cfg = AdamWConfig(lr=1e-2, total_steps=10, warmup_steps=1)
    w_shape = tuple(params["layers"]["moe"]["w_in"].shape)  # (L, E, K, N)

    fused = make_train_step(
        model, cfg, remat="none", backend=BackendConfig(gemm_backend="sfc_pallas", fused_optimizer=True, stochastic_round=False),
    )
    unfused = make_train_step(
        model, cfg, remat="none", backend=BackendConfig(gemm_backend="sfc_pallas"))
    jx_f = jax.make_jaxpr(fused)(params, adamw_init(params, with_gnorm=True), batch)
    jx_u = jax.make_jaxpr(unfused)(params, adamw_init(params), batch)
    n_f = _count_elementwise_at_shape(jx_f.jaxpr, w_shape)["n"]
    n_u = _count_elementwise_at_shape(jx_u.jaxpr, w_shape)["n"]
    assert n_u > 0, "unfused step lost its expert optimizer pass?"
    assert n_f == 0, (
        f"fused step still runs {n_f} elementwise optimizer ops at the "
        f"expert stack shape {w_shape}"
    )
