"""Fused-epilogue SFC GEMM: differential tests vs the jnp reference for
bias/activation/scale/residual/GLU epilogues (f32 accumulation), the
layer-inner single-launch structure (no (K_layers, M, N) HBM intermediate),
the replicated-form fallback, and the widened gemm_backend surface."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import (
    sfc_glu_matmul,
    sfc_grouped_glu_matmul,
    sfc_grouped_matmul,
    sfc_matmul,
)


def _rand(*shape, dtype=jnp.float32, seed=0):
    rng = np.random.default_rng([seed, *[int(s) for s in shape]])
    return jnp.asarray(rng.normal(size=shape), dtype)


def _act(name):
    from repro.kernels.sfc_gemm import activation_fn

    return activation_fn(name)


def _epilogue_ref(a, b, *, bias=None, activation=None, out_scale=None,
                  residual=None, out_dtype=None):
    """f32-accumulated oracle matching the kernel flush semantics."""
    acc = jnp.matmul(
        a.astype(jnp.float32), b.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    if bias is not None:
        acc = acc + bias.astype(jnp.float32)
    if activation is not None:
        acc = _act(activation)(acc)
    if out_scale is not None:
        acc = acc * out_scale
    if residual is not None:
        acc = acc + residual.astype(jnp.float32)
    return acc.astype(out_dtype or a.dtype)


def _glu_ref(a, bg, bv, *, activation="silu", bias=None, gate_bias=None,
             out_scale=None, residual=None, out_dtype=None):
    af = a.astype(jnp.float32)
    g = af @ bg.astype(jnp.float32)
    if gate_bias is not None:
        g = g + gate_bias.astype(jnp.float32)
    h = af @ bv.astype(jnp.float32)
    if bias is not None:
        h = h + bias.astype(jnp.float32)
    y = _act(activation)(g) * h
    if out_scale is not None:
        y = y * out_scale
    if residual is not None:
        y = y + residual.astype(jnp.float32)
    return y.astype(out_dtype or a.dtype)


def _tol(dtype):
    return 3e-5 if dtype == jnp.float32 else 6e-2


def _close(got, want, dtype, msg=""):
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=_tol(dtype), atol=_tol(dtype), err_msg=msg,
    )


# ---------------------------------------------------------------------------
# structural: the fused path is one launch, no replicated HBM intermediate
# ---------------------------------------------------------------------------


def _walk_jaxpr(jaxpr, pallas_eqns, shapes):
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            pallas_eqns.append(eqn)
        for v in eqn.outvars:
            aval = getattr(v, "aval", None)
            if aval is not None and hasattr(aval, "shape"):
                shapes.append(tuple(aval.shape))
        for val in eqn.params.values():
            _walk_param(val, pallas_eqns, shapes)


def _walk_param(val, pallas_eqns, shapes):
    if isinstance(val, jax.core.ClosedJaxpr):
        _walk_jaxpr(val.jaxpr, pallas_eqns, shapes)
    elif isinstance(val, jax.core.Jaxpr):
        _walk_jaxpr(val, pallas_eqns, shapes)
    elif isinstance(val, (tuple, list)):
        for v in val:
            _walk_param(v, pallas_eqns, shapes)


def _trace(fn, *args):
    jaxpr = jax.make_jaxpr(fn)(*args)
    pallas_eqns, shapes = [], []
    _walk_jaxpr(jaxpr.jaxpr, pallas_eqns, shapes)
    return pallas_eqns, shapes


def test_fused_path_single_launch_no_replicated_intermediate():
    """With k_layers=4 the fused path is one pallas_call and never holds a
    (K_layers, M, N) value; the replicated fallback launches twice and does
    (the HBM round-trip the layer-inner grid deletes)."""
    kl, m, n, k = 4, 64, 64, 128
    a, b = _rand(m, k), _rand(k, n, seed=1)

    def fused(a, b):
        return sfc_matmul(a, b, bm=16, bn=16, k_layers=kl, k_block_factor=1,
                          interpret=True)

    def fallback(a, b):
        return sfc_matmul(a, b, bm=16, bn=16, k_layers=kl, k_block_factor=1,
                          interpret=True, fuse=False)

    calls, shapes = _trace(fused, a, b)
    assert len(calls) == 1, f"fused path must be a single launch, saw {len(calls)}"
    assert (kl, m, n) not in shapes, "fused path materialized replicated C copies"

    calls, shapes = _trace(fallback, a, b)
    assert len(calls) == 2, "replicated fallback is gemm + add_reduce"
    assert (kl, m, n) in shapes, "fallback should hold the replicated copies"

    _close(fused(a, b), fallback(a, b), jnp.float32)


def test_fused_glu_single_launch():
    a, bg, bv = _rand(32, 64), _rand(64, 32, seed=1), _rand(64, 32, seed=2)

    def fused(a, bg, bv):
        return sfc_glu_matmul(a, bg, bv, bm=16, bn=16, k_layers=2,
                              k_block_factor=1, interpret=True)

    calls, _ = _trace(fused, a, bg, bv)
    assert len(calls) == 1, "GLU must be one dual-B launch, not two GEMMs"


# ---------------------------------------------------------------------------
# differential: epilogues vs jnp reference
# ---------------------------------------------------------------------------

EPILOGUE_CASES = [
    # (m, n, k, kwargs, use_bias, use_residual, dtype)
    (32, 32, 64, dict(bm=16, bn=16, k_layers=2, k_block_factor=1), True, False,
     jnp.float32),
    (48, 80, 96, dict(bm=16, bn=16, k_layers=2, k_block_factor=3), True, True,
     jnp.float32),
    (34, 21, 95, dict(bm=16, bn=16, k_layers=2, k_block_factor=2), True, True,
     jnp.float32),  # padded M/N/K
    (34, 21, 95, dict(bm=16, bn=16, k_layers=2, k_block_factor=2), True, True,
     jnp.bfloat16),
    (64, 32, 128, dict(bm=16, bn=16, k_layers=4, k_block_factor=1), False, True,
     jnp.bfloat16),
]


@pytest.mark.parametrize("activation", [None, "silu", "gelu", "relu"])
@pytest.mark.parametrize("m,n,k,kw,use_bias,use_res,dtype", EPILOGUE_CASES)
def test_epilogue_matches_reference(m, n, k, kw, use_bias, use_res, dtype,
                                    activation):
    a, b = _rand(m, k, dtype=dtype), _rand(k, n, dtype=dtype, seed=1)
    bias = _rand(n, dtype=dtype, seed=2) if use_bias else None
    res = _rand(m, n, dtype=dtype, seed=3) if use_res else None
    got = sfc_matmul(a, b, bias=bias, activation=activation, out_scale=0.5,
                     residual=res, interpret=True, **kw)
    want = _epilogue_ref(a, b, bias=bias, activation=activation,
                         out_scale=0.5, residual=res)
    assert got.shape == (m, n) and got.dtype == dtype
    _close(got, want, dtype, f"act={activation}")


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("lead", [(), (3,), (2, 2)])
def test_glu_matches_reference(lead, dtype):
    m, n, k = 19, 45, 53  # padded everywhere
    a = _rand(*lead, m, k, dtype=dtype)
    bg = _rand(k, n, dtype=dtype, seed=1)
    bv = _rand(k, n, dtype=dtype, seed=2)
    bias = _rand(n, dtype=dtype, seed=3)
    gbias = _rand(n, dtype=dtype, seed=4)
    got = sfc_glu_matmul(a, bg, bv, activation="silu", bias=bias,
                         gate_bias=gbias, bm=16, bn=16, k_layers=2,
                         k_block_factor=2, interpret=True)
    want = _glu_ref(a, bg, bv, activation="silu", bias=bias, gate_bias=gbias)
    assert got.shape == (*lead, m, n)
    _close(got, want, dtype)


def test_glu_fallback_matches_fused():
    a, bg, bv = _rand(34, 95), _rand(95, 21, seed=1), _rand(95, 21, seed=2)
    kw = dict(bm=16, bn=16, k_layers=2, k_block_factor=2, interpret=True)
    fused = sfc_glu_matmul(a, bg, bv, **kw)
    unfused = sfc_glu_matmul(a, bg, bv, fuse=False, **kw)
    _close(fused, unfused, jnp.float32)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_batched_fallback_reduce_per_element(dtype):
    """fuse=False with leading dims + k_layers>1 exercises the per-batch
    add_reduce (no transpose+reshape HBM fold of the copies)."""
    a = _rand(3, 37, 53, dtype=dtype)
    b = _rand(53, 21, dtype=dtype, seed=1)
    got = sfc_matmul(a, b, bm=16, bn=16, k_layers=2, k_block_factor=2,
                     interpret=True, fuse=False)
    _close(got, jnp.matmul(a, b), dtype)
    fused = sfc_matmul(a, b, bm=16, bn=16, k_layers=2, k_block_factor=2,
                       interpret=True)
    _close(got, fused, dtype)


GROUPED_CASES = [
    ((5, 0, 19, 32), 24, 18, jnp.float32),  # ragged incl. empty expert
    ((5, 0, 19, 32), 24, 18, jnp.bfloat16),
    ((1, 2, 3), 7, 9, jnp.float32),  # tiny odd dims
]


@pytest.mark.parametrize("group_sizes,k,n,dtype", GROUPED_CASES)
def test_grouped_epilogue_matches_reference(group_sizes, k, n, dtype):
    t = sum(group_sizes)
    e = len(group_sizes)
    a = _rand(t, k, dtype=dtype)
    w = _rand(e, k, n, dtype=dtype, seed=1)
    bias = _rand(e, n, dtype=dtype, seed=2)
    got = sfc_grouped_matmul(a, w, group_sizes, bias=bias, activation="gelu",
                             out_scale=2.0, bm=16, bn=16, interpret=True)
    off, parts = 0, []
    for ei, g in enumerate(group_sizes):
        parts.append(_epilogue_ref(a[off:off + g], w[ei], bias=bias[ei],
                                   activation="gelu", out_scale=2.0))
        off += g
    _close(got, jnp.concatenate(parts), dtype)


@pytest.mark.parametrize("group_sizes,k,n,dtype", GROUPED_CASES)
def test_grouped_glu_matches_reference(group_sizes, k, n, dtype):
    t = sum(group_sizes)
    e = len(group_sizes)
    a = _rand(t, k, dtype=dtype)
    wg = _rand(e, k, n, dtype=dtype, seed=1)
    wv = _rand(e, k, n, dtype=dtype, seed=2)
    got = sfc_grouped_glu_matmul(a, wg, wv, group_sizes, bm=16, bn=16,
                                 interpret=True)
    off, parts = 0, []
    for ei, g in enumerate(group_sizes):
        parts.append(_glu_ref(a[off:off + g], wg[ei], wv[ei]))
        off += g
    _close(got, jnp.concatenate(parts), dtype)


# ---------------------------------------------------------------------------
# widened gemm_backend surface
# ---------------------------------------------------------------------------

BACKENDS = ("xla", "sfc_pallas", "sfc_reference")


def test_backend_matmul_epilogue_agrees():
    from repro.core.gemm_backend import gemm_backend, matmul

    x, w = _rand(24, 40), _rand(40, 16, seed=1)
    bias = _rand(16, seed=2)
    res = _rand(24, 16, seed=3)
    want = _epilogue_ref(x, w, bias=bias, activation="silu", out_scale=0.5,
                         residual=res)
    for backend in BACKENDS:
        with gemm_backend(backend):
            got = matmul(x, w, bias=bias, activation="silu", out_scale=0.5,
                         residual=res)
        _close(got, want, jnp.float32, backend)


@pytest.mark.parametrize("shape", [(24, 40), (2, 12, 40), (4, 1, 40), (40,)])
def test_backend_glu_agrees(shape):
    from repro.core.gemm_backend import gemm_backend, glu_matmul

    x = _rand(*shape)
    wg, wv = _rand(40, 16, seed=1), _rand(40, 16, seed=2)
    want = _glu_ref(x if x.ndim > 1 else x[None], wg, wv)
    if x.ndim == 1:
        want = want[0]
    for backend in BACKENDS:
        with gemm_backend(backend):
            got = glu_matmul(x, wg, wv)
        assert got.shape == (*shape[:-1], 16)
        _close(got, want, jnp.float32, f"{backend}/{shape}")


def test_backend_grouped_glu_agrees():
    from repro.core.gemm_backend import gemm_backend, grouped_glu_matmul

    x = _rand(2, 4, 6, 16)  # (G, E, C, d)
    wg = _rand(4, 16, 12, seed=1)
    wv = _rand(4, 16, 12, seed=2)
    want = jax.nn.silu(jnp.einsum("gecd,edf->gecf", x, wg)) * jnp.einsum(
        "gecd,edf->gecf", x, wv
    )
    for backend in BACKENDS:
        with gemm_backend(backend):
            got = grouped_glu_matmul(x, wg, wv)
        assert got.shape == (2, 4, 6, 12)
        _close(got, want, jnp.float32, backend)


def test_mlp_fused_backend_matches_xla():
    """The whole gated MLP (dual-B fused under sfc_pallas) agrees with the
    unfused xla formulation."""
    from repro.core.gemm_backend import gemm_backend
    from repro.models.layers import mlp, mlp_init

    p = mlp_init(jax.random.PRNGKey(0), 24, 48, jnp.float32, gated=True)
    x = _rand(2, 10, 24)
    with gemm_backend("xla"):
        want = mlp(p, x)
    with gemm_backend("sfc_pallas"):
        got = mlp(p, x)
    _close(got, want, jnp.float32)


# ---------------------------------------------------------------------------
# tune-cache namespace + serving shapes for the fused variants
# ---------------------------------------------------------------------------


def test_tune_cache_glu_namespace(tmp_path):
    from repro.tune import KnobCache, Knobs

    cache = KnobCache(str(tmp_path / "knobs.json"))
    kg = Knobs(bm=64, bn=64, k_layers=1, k_block_factor=2, source="measured")
    kglu = Knobs(bm=32, bn=32, k_layers=1, k_block_factor=4, source="measured")
    cache.put(256, 256, 256, np.float32, "cpu", kg)
    cache.put(256, 256, 256, np.float32, "cpu", kglu, op="glu")
    assert cache.get(256, 256, 256, np.float32, "cpu").bm == 64
    assert cache.get(256, 256, 256, np.float32, "cpu", op="glu").bm == 32


def test_tune_gemm_glu_op_separate_winner(tmp_path):
    from repro.tune import KnobCache, tune_gemm

    cache = KnobCache(str(tmp_path / "knobs.json"))
    calls = []

    def fake_measure(m, n, k, dtype, knobs, *, op="gemm"):
        calls.append((op, knobs))
        return 1.0 / knobs.bm

    a = tune_gemm(96, 96, 96, np.float32, cache=cache, measure_fn=fake_measure)
    n_gemm = len(calls)
    b = tune_gemm(96, 96, 96, np.float32, cache=cache, measure_fn=fake_measure,
                  op="glu")
    assert len(calls) > n_gemm, "glu namespace must tune separately"
    assert all(op == "glu" for op, _ in calls[n_gemm:]), "op must reach measure_fn"
    b2 = tune_gemm(96, 96, 96, np.float32, cache=cache, measure_fn=fake_measure,
                   op="glu")
    assert b2.source == "cached" and (b2.bm, b2.bn) == (b.bm, b.bn)
    assert a.source == "measured"

    # a measurer that cannot take op must not silently mis-score a glu sweep
    def no_op_measure(m, n, k, dtype, knobs):
        return 1.0

    with pytest.raises(ValueError, match="op"):
        tune_gemm(64, 64, 64, np.float32, cache=cache,
                  measure_fn=no_op_measure, op="glu", force=True)


def test_engine_projection_shapes_tag_glu():
    from repro.configs import get_config
    from repro.serving.engine import ServingEngine

    cfg = get_config("yi_6b").reduced()  # llama-style: gated MLP
    params = None  # shapes only; no forward pass

    class _Shim(ServingEngine):
        def __init__(self, cfg):  # skip model build/jit
            self.cfg = cfg
            self.max_batch = 4

    ops = {s[0] for s in _Shim(cfg).projection_gemm_shapes(32)}
    assert "glu" in ops and "gemm" in ops
