"""ABFT checksum GEMM: detection, healing, guardrails, and integrity.

Covers the whole SDC story: the checksum identity and its tolerance, eager
raise vs traced counter detection, strict-mode NaN poisoning, the bitflip
fault differentials (transient heal / persistent quarantine / undetected
negative control), the train-loop rollback channel, checkpoint digests,
stale-calibration purging, the cross-process quarantine round-trip, and
the modeled overhead bound behind the ``abft/*`` bench gate.
"""

import json
import os
import subprocess
import sys
import warnings
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.gemm_backend import gemm_backend, matmul
from repro.kernels.ops import sfc_matmul, sfc_matmul_nt, sfc_matmul_tn
from repro.robust import (
    FaultSpec,
    SdcDetected,
    abft_mode,
    fault_injection,
    get_registry,
    reset_runtime_sdc,
    runtime_sdc_counts,
    runtime_sdc_total,
)
from repro.robust import abft
from repro.train.checkpoint import CheckpointIntegrityError, restore, save

REPO = Path(__file__).resolve().parents[1]


@pytest.fixture(autouse=True)
def _fresh_sdc_counters():
    """The runtime SDC counters are process-global (like the health
    registry); a detection leaking between tests would fail the
    no-false-positive assertions."""
    reset_runtime_sdc()
    yield
    reset_runtime_sdc()


def _rand(*shape, seed=0, dtype=jnp.float32):
    return jnp.asarray(
        np.random.RandomState(seed).randn(*shape).astype(np.float32), dtype
    )


# ---------------------------------------------------------------------------
# checksum math
# ---------------------------------------------------------------------------


def test_gemm_checksum_identity_within_tolerance():
    a, b = _rand(128, 96, seed=0), _rand(96, 64, seed=1)
    ref, mag = abft.gemm_checksum_ref(a, b)
    actual = jnp.sum(a @ b)
    assert float(jnp.abs(actual - ref)) <= float(abft.tolerance(mag, 96))


def test_nt_tn_checksum_identities():
    a, b = _rand(64, 96, seed=2), _rand(48, 96, seed=3)
    ref, mag = abft.nt_checksum_ref(a, b)
    assert float(jnp.abs(jnp.sum(a @ b.T) - ref)) <= float(
        abft.tolerance(mag, 96)
    )
    a, b = _rand(96, 64, seed=4), _rand(96, 48, seed=5)
    ref, mag = abft.tn_checksum_ref(a, b)
    assert float(jnp.abs(jnp.sum(a.T @ b) - ref)) <= float(
        abft.tolerance(mag, 96)
    )


def test_mode_resolution_env_and_overrides(monkeypatch):
    monkeypatch.delenv("REPRO_ABFT", raising=False)
    assert abft.current_mode("gemm") == "off"
    monkeypatch.setenv("REPRO_ABFT", "detect")
    assert abft.current_mode("gemm") == "detect"
    with abft_mode("off"):
        assert abft.current_mode("gemm") == "off"
        with abft_mode("strict", namespace="glu"):
            assert abft.current_mode("glu") == "strict"
            assert abft.current_mode("gemm") == "off"
    with pytest.raises(ValueError):
        abft_mode("paranoid").__enter__()


# ---------------------------------------------------------------------------
# verify(): eager raise, traced counters, strict poisoning
# ---------------------------------------------------------------------------


def test_verify_eager_raises_sdc_detected():
    out = jnp.ones((4, 4))
    with pytest.raises(SdcDetected, match="gemm"):
        abft.verify(
            "gemm", out, jnp.float32(100.0), jnp.float32(0.0),
            jnp.float32(1.0), contract_dim=64, mode="detect",
        )
    # clean checksum passes through untouched
    res = abft.verify(
        "gemm", out, jnp.float32(1.0), jnp.float32(1.0), jnp.float32(1.0),
        contract_dim=64, mode="detect",
    )
    np.testing.assert_array_equal(np.asarray(res), np.asarray(out))


def test_verify_traced_bumps_runtime_counters():
    fn = jax.jit(
        lambda out, chk, ref, mag: abft.verify(
            "gemm", out, chk, ref, mag, contract_dim=64, mode="detect"
        )
    )
    out = jnp.ones((4, 4))
    res = fn(out, jnp.float32(100.0), jnp.float32(0.0), jnp.float32(1.0))
    jax.effects_barrier()
    assert runtime_sdc_total() == 1
    assert runtime_sdc_counts() == {"gemm": 1}
    # detect mode does not perturb the traced output
    np.testing.assert_array_equal(np.asarray(res), np.ones((4, 4)))
    # mirrored into the health registry's sdc ledger
    assert get_registry().sdc_counts()["gemm"]["detected"] == 1
    # a clean traced call records nothing
    fn(out, jnp.float32(1.0), jnp.float32(1.0), jnp.float32(1.0))
    jax.effects_barrier()
    assert runtime_sdc_total() == 1


def test_verify_strict_nan_poisons_in_graph():
    fn = jax.jit(
        lambda out, chk, ref, mag: abft.verify(
            "gemm", out, chk, ref, mag, contract_dim=64, mode="strict"
        )
    )
    bad = fn(
        jnp.ones((4, 4)), jnp.float32(100.0), jnp.float32(0.0),
        jnp.float32(1.0),
    )
    assert np.isnan(np.asarray(bad)).all()
    clean = fn(
        jnp.ones((4, 4)), jnp.float32(1.0), jnp.float32(1.0),
        jnp.float32(1.0),
    )
    np.testing.assert_array_equal(np.asarray(clean), np.ones((4, 4)))


# ---------------------------------------------------------------------------
# the kernel checksum lane: clean runs never alarm
# ---------------------------------------------------------------------------


def test_sfc_ops_detect_clean_no_false_positive():
    a, b = _rand(96, 80, seed=6), _rand(80, 72, seed=7)
    c = sfc_matmul(a, b, abft="detect")  # eager: a mismatch would raise
    np.testing.assert_allclose(np.asarray(c), np.asarray(a @ b), rtol=2e-5)
    nt = sfc_matmul_nt(a, _rand(72, 80, seed=8), abft="detect")
    assert np.isfinite(np.asarray(nt)).all()
    tn = sfc_matmul_tn(_rand(96, 80, seed=9), _rand(96, 72, seed=10),
                       abft="detect")
    assert np.isfinite(np.asarray(tn)).all()


def test_no_false_positives_under_jit_and_grad():
    a, w = _rand(64, 64, seed=11), _rand(64, 64, seed=12)

    def loss(aa, ww):
        with gemm_backend("sfc_pallas"):
            return jnp.sum(matmul(aa, ww) ** 2)

    with abft_mode("detect"):
        val = jax.jit(loss)(a, w)
        grads = jax.jit(jax.grad(loss, argnums=(0, 1)))(a, w)
    jax.effects_barrier()
    assert runtime_sdc_total() == 0, runtime_sdc_counts()
    assert np.isfinite(float(val))
    for g in grads:
        assert np.isfinite(np.asarray(g)).all()


# ---------------------------------------------------------------------------
# bitflip differentials through the fallback ladder
# ---------------------------------------------------------------------------


def test_transient_bitflip_heals_on_retry():
    a, w = _rand(64, 64, seed=13), _rand(64, 64, seed=14)
    with gemm_backend("sfc_pallas"):
        clean = np.asarray(matmul(a, w))
    reg = get_registry()
    reg.reset()
    with fault_injection(FaultSpec("gemm", kind="bitflip", fires=1)) as st, \
            gemm_backend("sfc_pallas", abft="detect"):
        healed = np.asarray(matmul(a, w))
    assert [f[3] for f in st.fired] == ["bitflip"]
    # detected once, healed by the same-rung retry, nothing quarantined
    assert reg.sdc_counts() == {"gemm": {"detected": 1, "healed": 1}}
    assert reg.quarantined_namespaces() == ()
    np.testing.assert_array_equal(healed, clean)


def test_persistent_bitflip_quarantines_and_still_matches():
    a, w = _rand(64, 64, seed=15), _rand(64, 64, seed=16)
    with gemm_backend("sfc_pallas"):
        clean = np.asarray(matmul(a, w))
    reg = get_registry()
    reg.reset()
    with fault_injection(FaultSpec("gemm", kind="bitflip")), \
            gemm_backend("sfc_pallas", abft="detect"):
        healed = np.asarray(matmul(a, w))
    # both Pallas rungs quarantined with the sdc reason; the reference
    # rung served — outputs still match the unfaulted f32 path
    assert "gemm" in reg.quarantined_namespaces()
    reasons = {r["reason"] for r in reg.export_state().values()}
    assert reasons == {"sdc"}
    assert reg.sdc_counts()["gemm"]["detected"] >= 2
    np.testing.assert_allclose(healed, clean, rtol=1e-4, atol=1e-5)


def test_bitflip_negative_control_abft_off_goes_undetected():
    a, w = _rand(64, 64, seed=17), _rand(64, 64, seed=18)
    with gemm_backend("sfc_pallas"):
        clean = np.asarray(matmul(a, w))
    reg = get_registry()
    reg.reset()
    with fault_injection(FaultSpec("gemm", kind="bitflip")) as st, \
            gemm_backend("sfc_pallas"):  # abft off: the default
        corrupted = np.asarray(matmul(a, w))
    assert st.fired, "bitflip never fired"
    # exactly one element silently corrupted — finite, undetected
    diff = corrupted != clean
    assert int(diff.sum()) == 1
    assert np.isfinite(corrupted).all()
    assert runtime_sdc_total() == 0
    assert reg.sdc_counts() == {}
    assert reg.quarantined_namespaces() == ()


# ---------------------------------------------------------------------------
# train loop: the SDC rollback channel
# ---------------------------------------------------------------------------


class _SdcStep:
    """Host train_step: an 'sdc' batch lands a corrupt update AND trips
    the runtime counter (the in-graph detection fires after the update
    has already been applied — the ordering the rollback exists for)."""

    def __call__(self, params, opt_state, batch, lr_scale=None):
        if batch["sdc"]:
            abft._record_runtime_sdc("gemm", True, 1.0, 0.0)
            params = {"w": params["w"] + 1000.0}  # corruption landed
        else:
            params = {"w": params["w"] + 1.0}
        return params, opt_state, {"loss": 1.0}


def test_corruption_policy_rolls_back_on_sdc(tmp_path):
    from repro.train.checkpoint import CheckpointManager
    from repro.train.fault_tolerance import CorruptionPolicy, TrainLoop

    ckpt = CheckpointManager(str(tmp_path), interval=1000, keep=3)
    policy = CorruptionPolicy(max_rollbacks=2, rollback_on_sdc=True)
    loop = TrainLoop(_SdcStep(), lambda i: {"sdc": i == 3}, ckpt,
                     corruption_policy=policy)
    params = {"w": jnp.zeros((), jnp.float32)}
    opt = {"step": jnp.zeros((), jnp.int32)}

    # phase 1: three clean steps, checkpoint committed on exit
    params, opt, _ = loop.run(params, opt, num_steps=3, resume=False,
                              log_every=0, logger=lambda s: None)
    assert float(params["w"]) == 3.0

    # phase 2: data index 3 is poisoned — the corrupt +1000 update lands,
    # the counter delta trips, and the loop rolls back to step 3 and
    # skips the stream ahead; the remaining steps consume clean indices
    logs = []
    params, opt, history = loop.run(params, opt, num_steps=8, resume=True,
                                    log_every=0, logger=logs.append)
    assert float(params["w"]) == 8.0  # 3 + five clean steps, no 1000s
    assert any("SDC detected" in s and "rolled back" in s for s in logs)
    # the poisoned step was never recorded in history
    assert len(history) == 5


def test_corruption_policy_sdc_respects_max_rollbacks(tmp_path):
    from repro.train.checkpoint import CheckpointManager
    from repro.train.fault_tolerance import CorruptionPolicy, TrainLoop

    ckpt = CheckpointManager(str(tmp_path), interval=1000, keep=3)
    policy = CorruptionPolicy(max_rollbacks=1, rollback_on_sdc=True)
    loop = TrainLoop(_SdcStep(), lambda i: {"sdc": True}, ckpt,
                     corruption_policy=policy)
    params = {"w": jnp.zeros((), jnp.float32)}
    opt = {"step": jnp.zeros((), jnp.int32)}
    params, opt, _ = loop.run(params, opt, num_steps=1, resume=False,
                              log_every=0, logger=lambda s: None)
    # every step is poisoned: the step-1 checkpoint exists, so the loop
    # rolls back once, detects again, and refuses to thrash further
    with pytest.raises(RuntimeError, match="rollback"):
        loop.run(params, opt, num_steps=50, resume=True, log_every=0,
                 logger=lambda s: None)


def test_corruption_policy_sdc_channel_off_by_default_without_abft(tmp_path):
    """rollback_on_sdc=False ignores the counters entirely."""
    from repro.train.checkpoint import CheckpointManager
    from repro.train.fault_tolerance import CorruptionPolicy, TrainLoop

    ckpt = CheckpointManager(str(tmp_path), interval=1000, keep=3)
    policy = CorruptionPolicy(max_rollbacks=1, rollback_on_sdc=False)
    loop = TrainLoop(_SdcStep(), lambda i: {"sdc": i == 1}, ckpt,
                     corruption_policy=policy)
    params = {"w": jnp.zeros((), jnp.float32)}
    opt = {"step": jnp.zeros((), jnp.int32)}
    params, _, _ = loop.run(params, opt, num_steps=3, resume=False,
                            log_every=0, logger=lambda s: None)
    assert float(params["w"]) == 1002.0  # corruption sailed through


# ---------------------------------------------------------------------------
# checkpoint integrity digests
# ---------------------------------------------------------------------------


def _corrupt_one_leaf(step_dir: Path) -> Path:
    npy = sorted(step_dir.glob("*.npy"))[0]
    blob = bytearray(npy.read_bytes())
    blob[-1] ^= 0xFF  # flip bits in the data section, not the header
    npy.write_bytes(bytes(blob))
    return npy


def test_checkpoint_digest_catches_bit_rot(tmp_path):
    tree = {"w": jnp.arange(16, dtype=jnp.float32),
            "b": jnp.ones((4,), jnp.bfloat16)}
    save(str(tmp_path), 7, tree)
    step_dir = tmp_path / "step_00000007"
    # pristine restore verifies silently
    got, _ = restore(str(tmp_path), 7)
    np.testing.assert_array_equal(np.asarray(got["w"]), np.arange(16))
    _corrupt_one_leaf(step_dir)
    with pytest.raises(CheckpointIntegrityError, match="corrupt"):
        restore(str(tmp_path), 7)


def test_checkpoint_legacy_manifest_loads_unverified(tmp_path):
    save(str(tmp_path), 1, {"w": jnp.arange(8, dtype=jnp.float32)})
    mpath = tmp_path / "step_00000001" / "manifest.json"
    manifest = json.loads(mpath.read_text())
    for leaf in manifest["leaves"]:
        leaf.pop("digest", None)
    mpath.write_text(json.dumps(manifest))
    _corrupt_one_leaf(tmp_path / "step_00000001")
    got, _ = restore(str(tmp_path), 1)  # no digest -> loads, caveat emptor
    assert np.asarray(got["w"]).shape == (8,)


# ---------------------------------------------------------------------------
# knob cache: stale platform constants are purged on a kernel bump
# ---------------------------------------------------------------------------


def test_platform_constants_purged_on_kernel_version_bump(tmp_path):
    """A platform entry stamped by another kernel generation is purged.

    The whole-file meta gate already drops a cache written entirely by an
    older generation; the per-entry stamp covers the leak that gate can't
    see — an old-generation constants entry merged into a current-meta
    file (legacy files carry no meta, so their entries survive the file
    gate)."""
    import repro.tune.cache as cache_mod

    path = str(tmp_path / "knobs.json")
    cur = cache_mod.current_kernel_version()
    c = cache_mod.KnobCache(path)
    c.put_platform("cpu", {"gamma": 1e-12, "beta": 2e-9})
    got = cache_mod.KnobCache(path).get_platform("cpu")
    assert got == {"gamma": 1e-12, "beta": 2e-9}  # stamp stays internal

    # an entry calibrated against the previous kernel generation, inside
    # a file whose meta matches the current one
    key = cache_mod.KnobCache.platform_key("cpu", c.device)
    c._load()[key] = {"gamma": 9e-12, "beta": 9e-9, "kernel_version": cur - 1}
    c._save()
    cache_mod._WARNED_PLATFORM.clear()
    with pytest.warns(RuntimeWarning, match="purged"):
        assert cache_mod.KnobCache(path).get_platform("cpu") is None
    # the purge survived to disk — a fresh process finds nothing either
    assert cache_mod.KnobCache(path).get_platform("cpu") is None

    # warn-once per (path, backend): a second stale hit is silent
    c2 = cache_mod.KnobCache(path)
    c2._load()[key] = {"gamma": 9e-12, "kernel_version": cur - 1}
    c2._save()
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert cache_mod.KnobCache(path).get_platform("cpu") is None


def test_unstamped_legacy_platform_constants_are_purged(tmp_path):
    import repro.tune.cache as cache_mod

    path = str(tmp_path / "knobs.json")
    c = cache_mod.KnobCache(path)
    # a pre-stamping cache file: constants with no kernel_version at all
    c._load()[c.platform_key("cpu", c.device)] = {"gamma": 1e-12}
    c._save()
    cache_mod._WARNED_PLATFORM.clear()
    with pytest.warns(RuntimeWarning, match="<unstamped>"):
        assert cache_mod.KnobCache(path).get_platform("cpu") is None


# ---------------------------------------------------------------------------
# cross-process quarantine round-trip, lifted by a successful re-tune
# ---------------------------------------------------------------------------

_CHILD_QUARANTINE = """
import sys
from repro.robust.ladder import HealthRegistry
from repro.tune.cache import KnobCache
reg = HealthRegistry()
reg.quarantine("gemm", "sfc_pallas", "64x64x64|float32", "sdc",
               error=RuntimeError("ABFT checksum failure"))
reg.quarantine("gemm", "replicated", "64x64x64|float32", "sdc")
reg.save_to_cache(KnobCache(sys.argv[1]))
print("CHILD_SAVED")
"""

_CHILD_CHECK = """
import sys
from repro.robust.ladder import HealthRegistry
from repro.tune.cache import KnobCache
reg = HealthRegistry()
reg.load_from_cache(KnobCache(sys.argv[1]))
quarantined = reg.is_quarantined("gemm", "sfc_pallas", "64x64x64|float32")
print("CHILD_QUARANTINED" if quarantined else "CHILD_CLEAN")
"""


def _child(code: str, path: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, "-c", code, path],
        capture_output=True, text=True, timeout=180, env=env,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    return proc.stdout


def test_sdc_quarantine_cross_process_roundtrip_lifted_by_retune(tmp_path):
    from repro.tune.cache import KnobCache
    from repro.tune.tuner import tune_gemm

    path = str(tmp_path / "knobs.json")
    # process 1: detect SDC, quarantine, persist through the knob cache
    assert "CHILD_SAVED" in _child(_CHILD_QUARANTINE, path)

    # this process: the __health__| keys round-trip into the registry
    cache = KnobCache(path)
    reg = get_registry()
    reg.load_from_cache(cache)
    assert reg.is_quarantined("gemm", "sfc_pallas", "64x64x64|float32")
    assert reg.get_quarantine(
        "gemm", "sfc_pallas", "64x64x64|float32"
    ).reason == "sdc"

    # a successful (confirmed-measured) re-tune of the namespace vouches
    # for the kernel path again: quarantines lift AND the lift persists
    tune_gemm(64, 64, 64, np.float32, cache=cache,
              measure_fn=lambda m, n, k, dt, knobs: 1.0 / knobs.bm)
    assert not reg.is_quarantined("gemm", "sfc_pallas", "64x64x64|float32")

    # process 3: the healed state is what a fresh process loads
    assert "CHILD_CLEAN" in _child(_CHILD_CHECK, path)


# ---------------------------------------------------------------------------
# modeled overhead + the abft/* bench family
# ---------------------------------------------------------------------------


def test_abft_overhead_model_bounds():
    from repro.core.perf_model import abft_overhead, simulate_gemm

    o = abft_overhead(4096, 1024, 4096, dtype_bytes=2)
    # ref pass traffic dominates: one streaming read of A and B + the
    # 4-byte residual write
    assert o["bytes"] == (4096 * 4096 + 4096 * 1024) * 2 + 4
    assert o["flops"] > 0 and o["time_s"] > 0
    # perfect partitioning: per-worker time divides by the worker count
    o256 = abft_overhead(4096, 1024, 4096, dtype_bytes=2, n_workers=256)
    assert o256["time_s"] == pytest.approx(o["time_s"] / 256)
    # the dual-B GLU lane checks two B panels
    oglu = abft_overhead(4096, 1024, 4096, dtype_bytes=2, n_b_mats=2)
    assert oglu["bytes"] > o["bytes"]

    # the acceptance bound the bench rows gate: detect-mode overhead is
    # under 15% of the modeled forward-GEMM time on the paper cells
    for (m, n, k, n_b) in [(4096, 1024, 4096, 1), (4096, 8192, 4096, 1),
                           (4096, 11008, 4096, 2)]:
        g = simulate_gemm(m, n, k, n_workers=256, k_layers=1,
                          k_block_factor=2, n_b_mats=n_b)
        ov = abft_overhead(m, n, k, k_block_factor=2, n_b_mats=n_b,
                           n_workers=256)
        assert ov["time_s"] / g["time_s"] < 0.15


def test_bench_abft_rows_under_the_gate():
    sys.path.insert(0, str(REPO))
    try:
        from benchmarks import abft as bench_abft
        from benchmarks.common import records, reset_records

        reset_records()
        try:
            bench_abft.run()
            rows = {r["name"]: r for r in records()}
        finally:
            reset_records()
    finally:
        sys.path.remove(str(REPO))
    model_rows = [r for name, r in rows.items()
                  if name.startswith("abft/model/")]
    assert len(model_rows) >= 3
    for r in model_rows:
        rel = float(dict(kv.split("=") for kv in
                         r["derived"].split(";"))["rel"])
        assert rel < 0.15, r
        assert r["us_per_call"] > 0
